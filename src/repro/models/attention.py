"""Attention: GQA (llama/qwen/granite/gemma/seamless/jamba) and MLA
(deepseek-v3), with three execution paths:

  * ``flash_attention_jnp`` — chunked online-softmax over q/kv blocks
    (lax.scan), the XLA fallback and the oracle for the Pallas kernel in
    ``repro.kernels.flash_attention``.  Peak memory is O(block_q · block_k)
    per head instead of O(S²).
  * plain attention for short sequences (smoke tests).
  * decode paths — one query token against a (possibly ring-buffered) cache.

MLA decode uses the *absorbed* form: W_uk is folded into the query so
attention runs directly against the compressed kv-latent cache — the cache
stores kv_lora(512) + rope(64) per token instead of 2·H·hd.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .layers import P, apply_rope, causal_mask, prefix_lm_mask, rms_norm, shd

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def gqa_specs(cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = P((H, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = P((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = P((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def mla_specs(cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": P((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": P((m.q_lora_rank,), ("q_lora",), init="ones"),
        "w_uq": P((m.q_lora_rank, H, qk), ("q_lora", "heads", "head_dim")),
        "w_dkv": P((d, m.kv_lora_rank), ("embed", "kv_lora")),
        "kv_norm": P((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "w_kr": P((d, m.qk_rope_head_dim), ("embed", "head_dim")),
        "w_ukv": P((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                   ("kv_lora", "heads", "head_dim")),
        "wo": P((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------
def _plain_attention(q, k, v, mask, scale):
    """q [B,G,Hkv,S,D], k/v [B,1,Hkv,Sk,D]; mask [S,Sk]."""
    s = jnp.einsum("bghsd,bghtd->bghst", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bghst,bghtd->bghsd", p.astype(v.dtype), v)


@jax.named_scope("flash_attention")
def flash_attention_jnp(q, k, v, *, causal=True, prefix_len=None, window=None,
                        q_offset=0, block_q: int = 1024, block_k: int = 2048):
    """Chunked online-softmax attention.

    q [B, H, S, D]; k/v [B, Hkv, Sk, D] with H % Hkv == 0.
    Returns [B, H, S, D].  Memory: O(block_q · block_k) score tiles.
    """
    B, H, S, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # MLA: value head dim differs from qk head dim
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    # standard GQA pairing: q-head h uses kv-head h // G (h-major groups)
    qg = q.reshape(B, Hkv, G, S, D).transpose(0, 2, 1, 3, 4)  # [B,G,Hkv,S,D]
    kg = k[:, None]  # [B,1,Hkv,Sk,D]
    vg = v[:, None]

    if S * Sk <= 4096 * 4096 // 16 or S % block_q or Sk % block_k:
        # small/odd shapes: plain masked attention
        if prefix_len is not None:
            mask = prefix_lm_mask(S, Sk, prefix_len)
        elif causal:
            mask = causal_mask(S, Sk, q_offset=q_offset, window=window)
        else:
            mask = jnp.ones((S, Sk), bool)
        out = _plain_attention(qg, kg, vg, mask, scale)
        return out.transpose(0, 2, 1, 3, 4).reshape(B, H, S, Dv)

    nq, nk = S // block_q, Sk // block_k
    q_blocks = qg.reshape(B, G, Hkv, nq, block_q, D).transpose(3, 0, 1, 2, 4, 5)
    k_blocks = kg.reshape(B, 1, Hkv, nk, block_k, D).transpose(3, 0, 1, 2, 4, 5)
    v_blocks = vg.reshape(B, 1, Hkv, nk, block_k, Dv).transpose(3, 0, 1, 2, 4, 5)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk  # qblk [B,G,Hkv,bq,D]

        def kv_step(carry, kj_blks):
            m, l, acc = carry
            kj, kblk, vblk = kj_blks
            s = jnp.einsum("bghsd,bghtd->bghst", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            q_pos = qi * block_q + jnp.arange(block_q)[:, None] + q_offset
            k_pos = kj * block_k + jnp.arange(block_k)[None, :]
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= k_pos <= q_pos
            if window is not None:
                mask &= k_pos > (q_pos - window)
            if prefix_len is not None:
                mask |= (q_pos < prefix_len) & (k_pos < prefix_len)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bghst,bghtd->bghsd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, G, Hkv, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, G, Hkv, block_q), jnp.float32)
        a0 = jnp.zeros((B, G, Hkv, block_q, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_blocks, v_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), q_blocks))
    # outs [nq, B, G, Hkv, bq, Dv] -> [B, Hkv, G, nq, bq, Dv] -> [B, H, S, Dv]
    out = outs.transpose(1, 3, 2, 0, 4, 5).reshape(B, H, S, Dv)
    return out


@jax.named_scope("decode_attention")
def decode_attention(q, k_cache, v_cache, pos, *, window=None):
    """One-step decode: q [B,H,D] vs cache [B,Hkv,S,D]; pos scalar int.

    When ``window`` is set the cache is a ring buffer of length S=window
    that has been fully written (long-context serving); otherwise entries
    at indices > pos are masked out.  Softmax runs in fp32; the seq axis of
    the cache may be sharded — XLA turns the reductions into collectives.
    """
    B, H, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)  # q-head h -> kv-head h // G
    # f32 accumulation via preferred_element_type: bf16 operands stay bf16
    # (native on the MXU; avoids materialized f32 cache copies)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / (D ** 0.5)
    idx = jnp.arange(S)
    valid = idx <= pos if window is None else idx < jnp.minimum(pos + 1, S)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------
def gqa_forward(cfg, p, x, positions, *, causal=True, prefix_len=None,
                window=None, return_kv=False):
    """x [B,S,d] -> [B,S,d].  Full-sequence (train / prefill)."""
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    q = shd(q, "batch", "heads_act", "seq", None)
    out = flash_attention_jnp(q, k, v, causal=causal, prefix_len=prefix_len,
                              window=window)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def gqa_init_cache(cfg, batch: int, seq: int, dtype):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, KV, seq, hd), dtype),
        "v": jnp.zeros((batch, KV, seq, hd), dtype),
    }


def gqa_cache_spec(cfg, batch: int, seq: int):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": P((batch, KV, seq, hd), ("kv_batch", "kv_heads", "kv_seq", "head_dim")),
        "v": P((batch, KV, seq, hd), ("kv_batch", "kv_heads", "kv_seq", "head_dim")),
    }


def gqa_decode(cfg, p, x, cache, pos, *, window=None):
    """x [B,d] one token at ``pos``; cache {"k","v"} [B,KV,S,hd]."""
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"])
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    posv = jnp.asarray(pos)[None]
    q = apply_rope(q[:, None], posv, cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], posv, cfg.rope_theta)[:, 0]
    S = cache["k"].shape[2]
    slot = pos % S if window is not None else pos
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k[:, :, None, :].astype(cache["k"].dtype), (0, 0, slot, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v[:, :, None, :].astype(cache["v"].dtype), (0, 0, slot, 0))
    out = decode_attention(q, k_cache, v_cache, pos, window=window)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA module (deepseek-v3)
# ---------------------------------------------------------------------------
def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    ql = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", ql, p["w_uq"])
    qn, qr = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr  # [B,S,H,nope], [B,S,H,rope]


def mla_forward(cfg, p, x, positions, *, causal=True, return_kv=False):
    m = cfg.mla
    B, S, d = x.shape
    qn, qr = _mla_q(cfg, p, x, positions)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.rms_eps)   # [B,S,r]
    kr = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_ukv"])
    kn = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    H = cfg.n_heads
    q = jnp.concatenate([qn, qr], axis=-1).transpose(0, 2, 1, 3)   # [B,H,S,qk]
    k = jnp.concatenate([kn, jnp.broadcast_to(kr, (B, S, H, m.qk_rope_head_dim))],
                        axis=-1).transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    q = shd(q, "batch", "heads_act", "seq", None)
    out = flash_attention_jnp(q, k, vt, causal=causal)
    y = jnp.einsum("bhsk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (c_kv, kr[:, :, 0, :])
    return y


def mla_init_cache(cfg, batch: int, seq: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype),
    }


def mla_cache_spec(cfg, batch: int, seq: int):
    m = cfg.mla
    return {
        "c_kv": P((batch, seq, m.kv_lora_rank), ("kv_batch", "kv_seq", "kv_lora")),
        "k_rope": P((batch, seq, m.qk_rope_head_dim), ("kv_batch", "kv_seq", None)),
    }


def mla_decode(cfg, p, x, cache, pos):
    """Absorbed-form MLA decode: attention against the compressed cache."""
    m = cfg.mla
    B, d = x.shape
    posv = jnp.asarray(pos)[None]
    qn, qr = _mla_q(cfg, p, x[:, None, :], posv)
    qn, qr = qn[:, 0], qr[:, 0]                       # [B,H,nope/rope]
    c_new = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.rms_eps)     # [B,r]
    kr_new = apply_rope((x @ p["w_kr"])[:, None, :], posv, cfg.rope_theta)[:, 0]
    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new[:, None, :].astype(cache["c_kv"].dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new[:, None, :].astype(cache["k_rope"].dtype), (0, pos, 0))
    w_uk = p["w_ukv"][..., : m.qk_nope_head_dim]       # [r,H,nope]
    w_uv = p["w_ukv"][..., m.qk_nope_head_dim:]        # [r,H,v]
    q_abs = jnp.einsum("bhn,rhn->bhr", qn, w_uk)       # absorbed query
    s = jnp.einsum("bhr,bsr->bhs", q_abs, c_cache,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bsr->bhs", qr, kr_cache,
                       preferred_element_type=jnp.float32)
    s = s / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    S = c_cache.shape[1]
    s = jnp.where(jnp.arange(S) <= pos, s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", attn.astype(c_cache.dtype), c_cache)
    v = jnp.einsum("bhr,rhv->bhv", ctx, w_uv)
    y = jnp.einsum("bhv,hvd->bd", v, p["wo"])
    return y, {"c_kv": c_cache, "k_rope": kr_cache}
