"""Encoder-decoder model (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

The audio frontend (w2v-BERT conformer) is a STUB per the brief: the input
pipeline / ``input_specs()`` provides precomputed frame embeddings
[B, F, d_model].  Everything downstream (both transformer stacks, the
serving cache) is real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from .blocks import mlp_specs
from .layers import (P, rms_norm, shd, softmax_cross_entropy, stack_specs,
                     swiglu)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------
def _enc_layer_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": P((d,), ("embed",), init="ones"),
        "attn": attn.gqa_specs(cfg),
        "ln2": P((d,), ("embed",), init="ones"),
        "mlp": mlp_specs(cfg),
    }


def _dec_layer_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": P((d,), ("embed",), init="ones"),
        "self": attn.gqa_specs(cfg),
        "ln_x": P((d,), ("embed",), init="ones"),
        "cross": attn.gqa_specs(cfg),
        "ln2": P((d,), ("embed",), init="ones"),
        "mlp": mlp_specs(cfg),
    }


def encdec_specs(cfg) -> dict:
    d = cfg.d_model
    return {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed"), init="embed",
                   scale=0.02),
        "frame_proj": P((d, d), ("embed", "embed2")),
        "enc_layers": stack_specs(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "dec_layers": stack_specs(_dec_layer_specs(cfg), cfg.n_layers),
        "enc_norm": P((d,), ("embed",), init="ones"),
        "final_norm": P((d,), ("embed",), init="ones"),
        "lm_head": P((d, cfg.padded_vocab), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# Cross attention (full-seq and one-step against precomputed enc K/V)
# ---------------------------------------------------------------------------
def _cross_kv(cfg, p, enc_out):
    k = jnp.einsum("bfd,dhk->bhfk", enc_out, p["wk"])
    v = jnp.einsum("bfd,dhk->bhfk", enc_out, p["wv"])
    return k, v


def _cross_forward(cfg, p, x, enc_out):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k, v = _cross_kv(cfg, p, enc_out)
    out = attn.flash_attention_jnp(q, k, v, causal=False)
    return jnp.einsum("bhsk,hkd->bsd", out, p["wo"])


def _cross_decode(cfg, p, x, k, v):
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    out = attn.decode_attention(q, k, v, k.shape[2] - 1)
    return jnp.einsum("bhk,hkd->bd", out, p["wo"])


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------
def encode(cfg, params, frames):
    x = frames.astype(jnp.dtype(cfg.act_dtype)) @ params["frame_proj"]
    x = shd(x, "batch", "seq", "embed_act")
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, bp):
        a = rms_norm(h, bp["ln1"], cfg.rms_eps)
        h = h + attn.gqa_forward(cfg, bp["attn"], a, positions, causal=False)
        m = rms_norm(h, bp["ln2"], cfg.rms_eps)
        h = h + swiglu(m, bp["mlp"]["w_gate"], bp["mlp"]["w_up"],
                       bp["mlp"]["w_down"])
        return h, None

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.rms_eps)


def decode_stack(cfg, params, tokens, enc_out, *, collect_cache=False):
    x = jnp.take(params["embed"], tokens, axis=0).astype(enc_out.dtype)
    x = shd(x, "batch", "seq", "embed_act")
    positions = jnp.arange(x.shape[1])[None, :]

    def body(h, bp):
        a = rms_norm(h, bp["ln1"], cfg.rms_eps)
        out = attn.gqa_forward(cfg, bp["self"], a, positions, causal=True,
                               return_kv=collect_cache)
        if collect_cache:
            y, (k, v) = out
        else:
            y = out
        h = h + y
        c = rms_norm(h, bp["ln_x"], cfg.rms_eps)
        h = h + _cross_forward(cfg, bp["cross"], c, enc_out)
        m = rms_norm(h, bp["ln2"], cfg.rms_eps)
        h = h + swiglu(m, bp["mlp"]["w_gate"], bp["mlp"]["w_up"],
                       bp["mlp"]["w_down"])
        cache = None
        if collect_cache:
            ck, cv = _cross_kv(cfg, bp["cross"], enc_out)
            cache = {"k": k, "v": v, "xk": ck, "xv": cv}
        return h, cache

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return rms_norm(x, params["final_norm"], cfg.rms_eps), caches


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def encdec_loss(cfg, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    h, _ = decode_stack(cfg, params, batch["tokens"], enc_out)
    logits = h @ params["lm_head"].astype(h.dtype)
    logits = shd(logits, "batch", "seq", "vocab_act")
    ce = softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def encdec_cache_spec(cfg, batch: int, seq: int) -> dict:
    KV, hd, F = cfg.n_kv_heads, cfg.head_dim, cfg.n_frontend_tokens
    L = cfg.n_layers
    return {
        "k": P((L, batch, KV, seq, hd), ("layers", "kv_batch", "kv_heads", "kv_seq", "head_dim"), "zeros"),
        "v": P((L, batch, KV, seq, hd), ("layers", "kv_batch", "kv_heads", "kv_seq", "head_dim"), "zeros"),
        "xk": P((L, batch, KV, F, hd), ("layers", "kv_batch", "kv_heads", None, "head_dim"), "zeros"),
        "xv": P((L, batch, KV, F, hd), ("layers", "kv_batch", "kv_heads", None, "head_dim"), "zeros"),
    }


def encdec_init_cache(cfg, batch: int, seq: int, dtype):
    KV, hd, F = cfg.n_kv_heads, cfg.head_dim, cfg.n_frontend_tokens
    L = cfg.n_layers
    z = lambda *s: jnp.zeros(s, dtype)
    return {"k": z(L, batch, KV, seq, hd), "v": z(L, batch, KV, seq, hd),
            "xk": z(L, batch, KV, F, hd), "xv": z(L, batch, KV, F, hd)}


def encdec_prefill(cfg, params, batch, cache_len: int | None = None):
    """Encode frames + run the decoder over the prompt; build decode cache."""
    enc_out = encode(cfg, params, batch["frames"])
    h, caches = decode_stack(cfg, params, batch["tokens"], enc_out,
                             collect_cache=True)
    logits = h[:, -1] @ params["lm_head"].astype(h.dtype)
    S = batch["tokens"].shape[1]
    cache_len = cache_len or S
    full = encdec_init_cache(cfg, batch["tokens"].shape[0], cache_len, h.dtype)
    for name in ("k", "v"):
        src = caches[name].astype(full[name].dtype)
        pad = cache_len - src.shape[3]
        full[name] = jnp.pad(src, ((0, 0),) * 3 + ((0, pad),) + ((0, 0),))
    full["xk"] = caches["xk"].astype(full["xk"].dtype)
    full["xv"] = caches["xv"].astype(full["xv"].dtype)
    # cache left unconstrained at prefill — see the note in lm.lm_prefill
    return logits, full


def encdec_decode(cfg, params, token, pos, cache):
    x = jnp.take(params["embed"], token, axis=0).astype(
        jnp.dtype(cfg.act_dtype))

    def body(h, xs):
        bp, c = xs
        a = rms_norm(h, bp["ln1"], cfg.rms_eps)
        y, new_kv = attn.gqa_decode(cfg, bp["self"], a, {"k": c["k"], "v": c["v"]}, pos)
        h = h + y
        cx = rms_norm(h, bp["ln_x"], cfg.rms_eps)
        h = h + _cross_decode(cfg, bp["cross"], cx, c["xk"], c["xv"])
        m = rms_norm(h, bp["ln2"], cfg.rms_eps)
        h = h + swiglu(m, bp["mlp"]["w_gate"], bp["mlp"]["w_up"],
                       bp["mlp"]["w_down"])
        return h, {"k": new_kv["k"], "v": new_kv["v"], "xk": c["xk"], "xv": c["xv"]}

    h, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = h @ params["lm_head"].astype(h.dtype)
    return logits, new_cache
