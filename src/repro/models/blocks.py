"""Transformer-block composition: mixer (attn | mamba | mlstm | slstm) +
MLP (dense SwiGLU | MoE), pre-norm residual.  One function family per
concern; ``lm.py`` scans these over layer periods.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import P, rms_norm, shd, swiglu


def mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": P((d, f), ("embed", "mlp")),
        "w_up": P((d, f), ("embed", "mlp")),
        "w_down": P((f, d), ("mlp", "embed")),
    }


def block_specs(cfg, kind: str, idx_in_period: int) -> dict:
    """Spec tree for one layer of the given kind."""
    d = cfg.d_model
    if kind in ("mlstm", "slstm"):
        return {kind: (ssm.mlstm_specs(cfg) if kind == "mlstm"
                       else ssm.slstm_specs(cfg))}
    s: dict = {"ln1": P((d,), ("embed",), init="ones")}
    if kind == "attn":
        s["attn"] = (attn.mla_specs(cfg) if cfg.attn_kind == "mla"
                     else attn.gqa_specs(cfg))
    elif kind == "mamba":
        s["mamba"] = ssm.mamba_specs(cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cfg.d_ff or cfg.moe is not None:
        s["ln2"] = P((d,), ("embed",), init="ones")
        if cfg.is_moe_layer(idx_in_period):
            s["moe"] = moe_mod.moe_specs(cfg)
        else:
            s["mlp"] = mlp_specs(cfg)
    return s


def apply_mlp_part(cfg, bp, x):
    """Post-mixer MLP/MoE with pre-norm residual.  x [B,S,d]."""
    if "mlp" not in bp and "moe" not in bp:
        return x
    h = rms_norm(x, bp["ln2"], cfg.rms_eps)
    if "moe" in bp:
        B, S, d = h.shape
        y = moe_mod.moe_apply(cfg, bp["moe"], h.reshape(B * S, d)).reshape(B, S, d)
    else:
        y = swiglu(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
    y = shd(y, "batch", "seq", "embed_act")
    return x + y


def apply_block(cfg, kind: str, bp, x, positions, *, causal=True,
                prefix_len=None, window=None, state=None, return_kv=False):
    """Full-sequence application.  Returns (x, new_state_or_None)."""
    new_state = None
    if kind == "attn":
        h = rms_norm(x, bp["ln1"], cfg.rms_eps)
        if cfg.attn_kind == "mla":
            out = attn.mla_forward(cfg, bp["attn"], h, positions,
                                   causal=causal, return_kv=return_kv)
            if return_kv:
                y, (c_kv, k_rope) = out
                new_state = {"c_kv": c_kv, "k_rope": k_rope}
            else:
                y = out
        else:
            out = attn.gqa_forward(cfg, bp["attn"], h, positions,
                                   causal=causal, prefix_len=prefix_len,
                                   window=window, return_kv=return_kv)
            if return_kv:
                y, (k, v) = out
                new_state = {"k": k, "v": v}
            else:
                y = out
        x = x + y
    elif kind == "mamba":
        h = rms_norm(x, bp["ln1"], cfg.rms_eps)
        y, new_state = ssm.mamba_forward(cfg, bp["mamba"], h, state)
        x = x + y
    elif kind == "mlstm":
        y, new_state = ssm.mlstm_forward(cfg, bp["mlstm"], x, state)
        x = x + y
    elif kind == "slstm":
        y, new_state = ssm.slstm_forward(cfg, bp["slstm"], x, state)
        x = x + y
    else:  # pragma: no cover
        raise ValueError(kind)
    x = shd(x, "batch", "seq", "embed_act")
    x = apply_mlp_part(cfg, bp, x)
    return x, new_state


def decode_block(cfg, kind: str, bp, x, pos, *, window=None, state=None):
    """One-token decode.  x [B,d]; returns (x, new_state)."""
    if kind == "attn":
        h = rms_norm(x, bp["ln1"], cfg.rms_eps)
        if cfg.attn_kind == "mla":
            y, state = attn.mla_decode(cfg, bp["attn"], h, state, pos)
        else:
            y, state = attn.gqa_decode(cfg, bp["attn"], h, state, pos, window=window)
        x = x + y
    elif kind == "mamba":
        h = rms_norm(x, bp["ln1"], cfg.rms_eps)
        y, state = ssm.mamba_decode(cfg, bp["mamba"], h, state)
        x = x + y
    elif kind == "mlstm":
        y, state = ssm.mlstm_decode(cfg, bp["mlstm"], x, state)
        x = x + y
    elif kind == "slstm":
        y, state = ssm.slstm_decode(cfg, bp["slstm"], x, state)
        x = x + y
    else:  # pragma: no cover
        raise ValueError(kind)
    if "mlp" in bp or "moe" in bp:
        h = rms_norm(x, bp["ln2"], cfg.rms_eps)
        if "moe" in bp:
            y = moe_mod.moe_apply(cfg, bp["moe"], h)
        else:
            y = swiglu(h, bp["mlp"]["w_gate"], bp["mlp"]["w_up"], bp["mlp"]["w_down"])
        x = x + y
    return x, state


def block_state_kind(cfg, kind: str) -> str | None:
    """Which decode-state structure a block kind needs."""
    return {"attn": "kv", "mamba": "mamba", "mlstm": "mlstm", "slstm": "slstm"}[kind]


def block_cache_spec(cfg, kind: str, batch: int, seq: int):
    if kind == "attn":
        if cfg.attn_kind == "mla":
            return attn.mla_cache_spec(cfg, batch, seq)
        w = cfg.sliding_window
        s = min(seq, w) if (w is not None and cfg.family == "hybrid") else seq
        return attn.gqa_cache_spec(cfg, batch, s)
    if kind == "mamba":
        return ssm.mamba_state_spec(cfg, batch)
    if kind == "mlstm":
        return ssm.mlstm_state_spec(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_state_spec(cfg, batch)
    raise ValueError(kind)


def block_init_cache(cfg, kind: str, batch: int, seq: int, dtype):
    if kind == "attn":
        if cfg.attn_kind == "mla":
            return attn.mla_init_cache(cfg, batch, seq, dtype)
        w = cfg.sliding_window
        s = min(seq, w) if (w is not None and cfg.family == "hybrid") else seq
        return attn.gqa_init_cache(cfg, batch, s, dtype)
    if kind == "mamba":
        return ssm.mamba_init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return ssm.mlstm_init_state(cfg, batch)
    if kind == "slstm":
        return ssm.slstm_init_state(cfg, batch)
    raise ValueError(kind)
