"""Model-layer primitives + the parameter-spec machinery.

Every parameter is declared as a ``P`` spec leaf: shape + *logical axes*
(names like "embed", "heads", "vocab").  The launch layer maps logical axes
to mesh axes (FSDP/TP/EP/SP) via divisibility-aware rules — the same spec
tree drives:
  * real initialization (smoke tests, examples),
  * abstract initialization (dry-run: ShapeDtypeStruct + NamedSharding),
  * checkpoint layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape, logical axes (one name per dim), init kind."""

    shape: tuple
    axes: tuple
    init: str = "normal"   # normal | zeros | ones | embed | small
    scale: float | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: P, key, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) >= 1 else 1
    if len(spec.shape) >= 2:
        fan_in = int(np.prod(spec.shape[:-1]))
    scale = spec.scale
    if scale is None:
        scale = {"normal": 1.0 / np.sqrt(max(fan_in, 1)),
                 "embed": 1.0,
                 "small": 0.01}[spec.init]
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_from_spec(spec_tree, key, dtype=jnp.float32):
    """Materialize a parameter pytree from a spec tree (real arrays)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_from_spec(spec_tree, dtype, spec_to_sharding: Callable[[P], Any] | None = None):
    """ShapeDtypeStruct pytree (dry-run path; no allocation)."""

    def leaf(s: P):
        sh = spec_to_sharding(s) if spec_to_sharding is not None else None
        if sh is not None:
            return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh)
        return jax.ShapeDtypeStruct(s.shape, dtype)

    return jax.tree.map(leaf, spec_tree, is_leaf=lambda x: isinstance(x, P))


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prefix every spec in the tree with a stacked leading dim (scan axis)."""

    def leaf(s: P):
        return P((n, *s.shape), (axis_name, *s.axes), s.init, s.scale)

    return jax.tree.map(leaf, spec_tree, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation sharding constraints (logical-axis based; resolved by launch/)
# ---------------------------------------------------------------------------
_CONSTRAIN: list[Callable] = []  # stack of fn(x, axes) -> x


def push_constrainer(fn) -> None:
    _CONSTRAIN.append(fn)


def pop_constrainer() -> None:
    _CONSTRAIN.pop()


def shd(x, *axes):
    """Apply the active logical-axis sharding constraint (no-op if none)."""
    if _CONSTRAIN:
        return _CONSTRAIN[-1](x, axes)
    return x


# ---------------------------------------------------------------------------
# Numeric primitives
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dt)


@jax.named_scope("swiglu")
def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return h @ w_down


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu((x @ w_up + b_up).astype(jnp.float32)).astype(x.dtype)
    return h @ w_down + b_down


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., T, n_heads?, head_dim]; positions broadcastable to [..., T]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., T, hd/2]
    # broadcast over any head axis between T and head_dim
    extra = x.ndim - angles.ndim
    for _ in range(extra):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset=0, window: int | None = None):
    """[q_len, kv_len] boolean mask (True = attend)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    m = k_pos <= q_pos
    if window is not None:
        m &= k_pos > (q_pos - window)
    return m


def prefix_lm_mask(q_len: int, kv_len: int, prefix_len: int):
    """Prefix positions attend bidirectionally; the rest is causal."""
    m = causal_mask(q_len, kv_len)
    q_pos = jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    bidir = (q_pos < prefix_len) & (k_pos < prefix_len)
    return m | bidir


# ---------------------------------------------------------------------------
# Cross entropy (padded-vocab aware)
# ---------------------------------------------------------------------------
@jax.named_scope("cross_entropy")
def softmax_cross_entropy(logits, labels, vocab_size: int):
    """logits [..., Vp] fp32; labels int [...]; ids >= vocab_size are padding
    columns and masked out of the partition function."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab_size:
        pad = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad, -1e30, logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - ll
