"""Decoder-only language model (covers dense / moe / ssm / hybrid / vlm).

Layers are grouped into *periods* (``cfg.layer_pattern``) and scanned with
``lax.scan`` — parameters are stacked [n_periods, ...] so the HLO contains
one period body regardless of depth (essential for compiling the 61-layer
671B config).  Remat wraps the period body.

Three entry points per model:
  * ``lm_loss``      — training loss over a (tokens, labels) batch.
  * ``lm_prefill``   — full-sequence forward returning last-position logits
                       and the decode cache (KV / SSM states).
  * ``lm_decode``    — one token against the cache at position ``pos``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .blocks import (apply_block, block_cache_spec, block_init_cache,
                     block_specs, decode_block)
from .layers import (P, abstract_from_spec, init_from_spec, rms_norm, shd,
                     softmax_cross_entropy, stack_specs)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def lm_specs(cfg) -> dict:
    d = cfg.d_model
    period = {f"sub{i}": block_specs(cfg, kind, i)
              for i, kind in enumerate(cfg.layer_pattern)}
    specs: dict = {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed"), init="embed",
                   scale=0.02),
        "layers": stack_specs(period, cfg.n_periods),
        "final_norm": P((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((d, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.frontend == "vision":
        specs["vision_proj"] = P((d, d), ("embed", "embed2"))
    if cfg.mtp:
        specs["mtp"] = {
            "proj": P((2 * d, d), ("inner", "embed")),
            "block": block_specs(cfg, "attn", 0),
            "norm": P((d,), ("embed",), init="ones"),
        }
    return specs


def _stateful(kind: str) -> bool:
    return kind in ("mamba", "mlstm", "slstm")


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------
def lm_backbone(cfg, params, x, positions, *, causal=True, prefix_len=None,
                window=None, collect_cache=False, init_states=None):
    """x [B,S,d] -> (h [B,S,d], per-period states or None)."""
    pattern = cfg.layer_pattern
    B, S, _ = x.shape

    def period_body(carry, xs):
        h = carry
        bp, states_in = xs
        states_out = {}
        for i, kind in enumerate(pattern):
            st = None
            if states_in is not None and f"sub{i}" in states_in:
                st = states_in[f"sub{i}"]
            h, st_new = apply_block(
                cfg, kind, bp[f"sub{i}"], h, positions, causal=causal,
                prefix_len=prefix_len, window=window, state=st,
                return_kv=collect_cache)
            if collect_cache and st_new is not None:
                states_out[f"sub{i}"] = st_new
        return h, (states_out if collect_cache else None)

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        period_body = jax.checkpoint(period_body, policy=policy,
                                     prevent_cse=False)

    xs = (params["layers"], init_states)
    h, caches = jax.lax.scan(period_body, x, xs)
    return h, caches


def _embed(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.act_dtype) if isinstance(cfg.act_dtype, str) else cfg.act_dtype)
    return shd(x, "batch", "seq", "embed_act")


def _logits(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return shd(logits, "batch", "seq", "vocab_act")


def _full_init_states(cfg, batch, dtype):
    """Zero initial states for stateful blocks, stacked over periods
    (needed so lax.scan xs have a leading n_periods axis)."""
    pattern = cfg.layer_pattern
    if not any(_stateful(k) for k in pattern):
        return None
    per = {}
    for i, kind in enumerate(pattern):
        if _stateful(kind):
            st = block_init_cache(cfg, kind, batch, 0, dtype)
            per[f"sub{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_periods, *a.shape)), st)
    return per


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------
def lm_loss(cfg, params, batch):
    """batch: tokens [B,S], labels [B,S] (+ patches [B,P,d] for vlm).
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, S_text = tokens.shape
    x = _embed(cfg, params, tokens)
    prefix_len = None
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    states = _full_init_states(cfg, B, x.dtype)
    h, _ = lm_backbone(cfg, params, x, positions, causal=True,
                       prefix_len=prefix_len, init_states=states)
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    if prefix_len:
        h_text = h[:, prefix_len:]
    else:
        h_text = h
    logits = _logits(cfg, params, h_text)
    ce = softmax_cross_entropy(logits, labels, cfg.vocab_size)
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    metrics = {"loss": loss, "tokens": jnp.sum(mask)}

    if cfg.mtp:  # multi-token prediction: predict t+2 from (h_t, emb_{t+1})
        mp = params["mtp"]
        emb_next = _embed(cfg, params, tokens)[:, 1:]          # emb of t+1
        h_in = jnp.concatenate(
            [rms_norm(h_text[:, :-1], mp["norm"], cfg.rms_eps), emb_next],
            axis=-1) @ mp["proj"]
        pos2 = jnp.arange(h_in.shape[1])[None, :]
        h2, _ = apply_block(cfg, "attn", mp["block"], h_in, pos2, causal=True)
        logits2 = _logits(cfg, params, h2)
        labels2 = labels[:, 1:]
        ce2 = softmax_cross_entropy(logits2, labels2, cfg.vocab_size)
        mask2 = (labels2 >= 0).astype(jnp.float32)
        mtp_loss = jnp.sum(ce2 * mask2) / jnp.maximum(jnp.sum(mask2), 1.0)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss
        metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------
def lm_cache_spec(cfg, batch: int, seq: int) -> dict:
    per = {}
    for i, kind in enumerate(cfg.layer_pattern):
        per[f"sub{i}"] = block_cache_spec(cfg, kind, batch, seq)
    return jax.tree.map(
        lambda s: P((cfg.n_periods, *s.shape), ("layers", *s.axes), "zeros"),
        per, is_leaf=lambda x: isinstance(x, P))


def lm_init_cache(cfg, batch: int, seq: int, dtype):
    per = {}
    for i, kind in enumerate(cfg.layer_pattern):
        st = block_init_cache(cfg, kind, batch, seq, dtype)
        per[f"sub{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods, *a.shape)).copy(), st)
    return per


def lm_prefill(cfg, params, batch, cache_len: int | None = None):
    """Forward over a prompt; returns (last-position logits [B,V], cache)."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = _embed(cfg, params, tokens)
    prefix_len = None
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    states = _full_init_states(cfg, B, x.dtype)
    h, caches = lm_backbone(cfg, params, x, positions, causal=True,
                            prefix_len=prefix_len, collect_cache=True,
                            init_states=states)
    h = rms_norm(h[:, -1], params["final_norm"], cfg.rms_eps)
    logits = _logits(cfg, params, h[:, None])[:, 0]
    # assemble decode caches: attn K/V land in fixed buffers of cache_len
    cache_len = cache_len or S
    full = lm_init_cache(cfg, B, cache_len, x.dtype)
    def place(dst, src):
        if src.shape == dst.shape:
            return src.astype(dst.dtype)
        src = src.astype(dst.dtype)
        # single differing axis = the sequence axis of an attention cache
        for ax, (d, s) in enumerate(zip(dst.shape, src.shape)):
            if d > s:   # shorter prompt: pad future slots at the end
                pads = [(0, dd - ss) if i == ax else (0, 0)
                        for i, (dd, ss) in enumerate(zip(dst.shape, src.shape))]
                return jnp.pad(src, pads)
            if d < s:   # sliding-window ring buffer: keep the last W entries
                idx = [slice(None)] * src.ndim
                idx[ax] = slice(s - d, s)
                return src[tuple(idx)]
        return src
    if caches is not None:
        for sub, st in caches.items():
            full[sub] = jax.tree.map(place, full[sub], st)
    # NOTE on cache sharding at prefill: measured on the dry-run, explicit
    # constraints here only hurt — requesting the decode layout (seq@model)
    # back-propagates into prefill attention and forces per-layer K/V
    # all-gathers (28 TB on llama3b prefill_32k), while batch-only
    # constraints force the remaining axes REPLICATED (seamless: 1.6 →
    # 18.8 GB/dev).  Unconstrained, GSPMD shards the assembled cache from
    # the producing attention's layout; the prefill→decode hand-off then
    # reshards once (separate jit programs — the production pattern).
    return logits, full


def lm_decode(cfg, params, token, pos, cache):
    """token [B] int32; pos scalar int32; cache from lm_init_cache/prefill."""
    x = jnp.take(params["embed"], token, axis=0).astype(
        jnp.dtype(cfg.act_dtype) if isinstance(cfg.act_dtype, str) else cfg.act_dtype)
    x = shd(x, "batch", "embed_act")
    pattern = cfg.layer_pattern
    window = cfg.sliding_window if cfg.family == "hybrid" else None

    def period_body(carry, xs):
        h = carry
        bp, cache_in = xs
        cache_out = {}
        for i, kind in enumerate(pattern):
            st = cache_in[f"sub{i}"]
            w = window if kind == "attn" else None
            h, st_new = decode_block(cfg, kind, bp[f"sub{i}"], h, pos,
                                     window=w, state=st)
            cache_out[f"sub{i}"] = st_new
        return h, cache_out

    h, new_cache = jax.lax.scan(period_body, x, (params["layers"], cache))
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = _logits(cfg, params, h[:, None])[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def lm_init(cfg, key, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return init_from_spec(lm_specs(cfg), key, dtype)
