"""Mixture-of-Experts layer with sort-based token dispatch (EP-shardable).

Dispatch avoids the O(T·E·C) one-hot tensors of the classic einsum MoE:
token→expert assignments are argsorted by expert id, positions within each
expert are computed from the sorted ids, and tokens are scattered into
fixed-capacity expert buffers [E, C, d].  The expert matmuls are einsums
over the (sharded) expert axis; capacity overflow drops tokens (standard
capacity-factor routing).  The router runs in fp32.

Sharding: experts shard over the "model" mesh axis (expert parallelism);
the scatter/gather across expert shards lowers to all-to-all-style
collectives under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P, shd


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert_ff
    # Expert weights: EP over the model axis + FSDP over data on d.
    # (§Perf iteration 2, REFUTED: Megatron-style column/row sharding of
    # expert_ff over the data axis was predicted to cut the per-layer
    # [E,C,f] partial-sum all-reduces ~10×; measured on the 671B train cell
    # it made collectives WORSE — 200s → 247s — because the backward pass
    # then all-gathers activations and re-reduces grads for the
    # column-sharded weights.  Reverted; see EXPERIMENTS.md §Perf.)
    s = {
        "router": P((d, m.n_experts), ("embed", "experts"), init="small"),
        "w_gate": P((m.n_experts, d, f), ("experts", "embed", "expert_ff")),
        "w_up": P((m.n_experts, d, f), ("experts", "embed", "expert_ff")),
        "w_down": P((m.n_experts, f, d), ("experts", "expert_ff", "embed")),
    }
    if m.n_shared:
        fs = m.d_expert_ff * m.n_shared
        s["ws_gate"] = P((d, fs), ("embed", "mlp"))
        s["ws_up"] = P((d, fs), ("embed", "mlp"))
        s["ws_down"] = P((fs, d), ("mlp", "embed"))
    return s


def _dispatch_group(cfg, p, x):
    """Sort-based dispatch for ONE token group.  x [T, d] -> [T, d]."""
    m = cfg.moe
    T, d = x.shape
    E, k = m.n_experts, m.top_k
    C = max(int(T * k / E * m.capacity_factor), 1)
    C = -(-C // 8) * 8  # pad capacity to a multiple of 8 (VPU lanes)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)                    # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize

    flat_e = top_e.reshape(-1)                                # [T*k]
    flat_p = top_p.reshape(-1)
    tok = jnp.arange(T * k, dtype=jnp.int32) // k             # token of each slot

    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    sorted_tok = tok[order]
    sorted_p = flat_p[order]
    # position of each sorted slot within its expert bucket
    start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(T * k, dtype=jnp.int32) - start[sorted_e].astype(jnp.int32)
    keep = pos < C
    dst_c = jnp.where(keep, pos, C - 1)

    # scatter tokens into expert buffers [E, C, d]
    buf = jnp.zeros((E, C, d), x.dtype)
    vals = x[sorted_tok] * keep[:, None].astype(x.dtype)
    buf = buf.at[sorted_e, dst_c].add(vals, mode="drop")

    # expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    # gather back + combine weighted by router prob
    y_slots = out_buf[sorted_e, dst_c] * (keep * sorted_p).astype(x.dtype)[:, None]
    return jnp.zeros((T, d), x.dtype).at[sorted_tok].add(y_slots, mode="drop")


def dispatch_groups(T: int, target: int = 16) -> int:
    """Largest group count ≤ target dividing T (production shapes hit 16)."""
    g = min(target, T)
    while T % g:
        g -= 1
    return g


@jax.named_scope("moe_apply")
def moe_apply(cfg, p, x):
    """x [T, d] -> [T, d] (callers flatten batch×seq).

    GROUP-WISE dispatch (§Perf iteration 1 on the 671B train cell): the
    token axis is split into groups aligned with the data-parallel
    sharding, and each group sorts/scatters only its own tokens.  A global
    dispatch makes every slot tensor [T_global·k, d] *replicated* (the
    argsort permutation crosses data shards), which lowered to ~41 TB/dev
    of all-reduce on deepseek train_4k; per-group dispatch keeps all
    gather/scatter local to the shard and leaves only the expert einsums'
    EP communication.
    """
    T, d = x.shape
    G = dispatch_groups(T)
    xg = x.reshape(G, T // G, d)
    xg = shd(xg, "batch", None, None)
    yg = jax.vmap(lambda t: _dispatch_group(cfg, p, t))(xg)
    yg = shd(yg, "batch", None, None)
    y = yg.reshape(T, d)

    # shared (always-on) experts
    m = cfg.moe
    if m.n_shared:
        g = x @ p["ws_gate"]
        u = x @ p["ws_up"]
        y = y + (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ p["ws_down"]
    return y


def moe_load_balance_loss(cfg, p, x):
    """Auxiliary load-balancing loss (Switch-style f·P); reported as a
    metric and optionally added to the training objective."""
    m = cfg.moe
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jax.lax.top_k(probs, m.top_k)[1]
    ind = jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.float32).sum(axis=1)
    f = jnp.mean(ind, axis=0)          # fraction routed per expert
    pmean = jnp.mean(probs, axis=0)    # mean router prob per expert
    return m.n_experts * jnp.sum(f * pmean)
