"""State-space / recurrent blocks: Mamba (jamba) and xLSTM (mLSTM + sLSTM).

TPU adaptation notes (DESIGN.md §3/§5):
  * Mamba's selective scan runs CHUNKWISE: a lax.scan over sequence chunks
    carrying the SSM state, with a log-depth ``lax.associative_scan`` inside
    each chunk.  Peak intermediates are O(B·chunk·d_inner·d_state) instead
    of O(B·S·d_inner·d_state).
  * mLSTM uses the stabilized chunkwise-parallel form: intra-chunk decay
    matrices (MXU matmuls) + inter-chunk (C, n, m) state carry.  The
    matrix-memory update C += i·v kᵀ is a *rank-1 factorizable update* —
    the same structure as the paper's Sec. 5 (lock #2).
  * sLSTM has true hidden-to-hidden recurrence (block-diagonal R) and is
    inherently sequential: lax.scan over time.  This is the arch's nature,
    not an implementation limit.

Each block kind provides: ``*_specs``, ``*_forward`` (full sequence,
returns final state), ``*_decode`` (one step), ``*_state_spec``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import P, rms_norm, shd

NEG_INF = -1e30


def _causal_conv1d(x, w, b):
    """Depthwise causal conv: x [B,S,C], w [K,C], b [C]."""
    K = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[K - 1 - i]
    return out + b


def _conv1d_step(x_new, conv_state, w, b):
    """x_new [B,C]; conv_state [B,K-1,C] (previous inputs, oldest first)."""
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


# ===========================================================================
# Mamba (S6)
# ===========================================================================
def mamba_dims(cfg):
    di = cfg.ssm.expand * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return di, dt_rank, cfg.ssm.d_state


def mamba_specs(cfg) -> dict:
    d = cfg.d_model
    di, dt_rank, N = mamba_dims(cfg)
    K = cfg.ssm.d_conv
    return {
        "in_proj": P((d, 2 * di), ("embed", "inner")),
        "conv_w": P((K, di), (None, "inner")),
        "conv_b": P((di,), ("inner",), init="zeros"),
        "x_proj": P((di, dt_rank + 2 * N), ("inner", None)),
        "dt_w": P((dt_rank, di), (None, "inner")),
        "dt_b": P((di,), ("inner",), init="ones"),
        "A_log": P((di, N), ("inner", None), init="ones"),
        "D": P((di,), ("inner",), init="ones"),
        "out_proj": P((di, d), ("inner", "embed")),
    }


def _mamba_scan(a, b, Cp, h0, chunk: int):
    """h_t = a_t·h_{t-1} + b_t; emits y_t = C_t·h_t per chunk so the full
    [B,S,di,N] state tensor is never materialized (16× smaller residuals).

    a/b [B,S,di,N]; Cp [B,S,N]; h0 [B,di,N].  Returns (h_last, y [B,S,di]).
    """
    B, S, di, N = a.shape
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L
    a_c = a.reshape(B, nc, L, di, N).transpose(1, 0, 2, 3, 4)
    b_c = b.reshape(B, nc, L, di, N).transpose(1, 0, 2, 3, 4)
    C_c = Cp.reshape(B, nc, L, N).transpose(1, 0, 2, 3)

    def combine(c1, c2):
        return c2[0] * c1[0], c2[0] * c1[1] + c2[1]

    def outer(h, xs):
        ac, bc, cc = xs  # [B,L,di,N], [B,L,N]
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = aa * h[:, None] + bb
        y = jnp.einsum("bldn,bln->bld", hs, cc)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(outer, h0, (a_c, b_c, C_c))
    return h_last, ys.transpose(1, 0, 2, 3).reshape(B, S, di)


@jax.named_scope("mamba")
def mamba_forward(cfg, p, x, state=None):
    """x [B,S,d] -> (y [B,S,d], state)."""
    B, S, d = x.shape
    di, dt_rank, N = mamba_dims(cfg)
    if state is None:
        state = mamba_init_state(cfg, B, x.dtype)
    xz = x @ p["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    xm = shd(xm, "batch", "seq", "inner_act")
    # causal depthwise conv (prepend carried conv state)
    K = cfg.ssm.d_conv
    xm_ext = jnp.concatenate([state["conv"].astype(xm.dtype), xm], axis=1)
    xm_c = _causal_conv1d(xm_ext, p["conv_w"], p["conv_b"])[:, K - 1:]
    new_conv = xm_ext[:, -(K - 1):] if K > 1 else state["conv"]
    xm_c = jax.nn.silu(xm_c.astype(jnp.float32)).astype(x.dtype)

    dbc = xm_c @ p["x_proj"]
    dt_in, Bp, Cp = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"].astype(jnp.float32))        # [B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # [di,N]
    a = jnp.exp(dt[..., None] * A)                               # [B,S,di,N]
    bterm = (dt * xm_c.astype(jnp.float32))[..., None] * Bp.astype(jnp.float32)[:, :, None, :]
    h_last, y = _mamba_scan(a, bterm, Cp.astype(jnp.float32), state["h"],
                            cfg.ssm.chunk)
    y = y + p["D"].astype(jnp.float32) * xm_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"]
    return out, {"h": h_last, "conv": new_conv.astype(state["conv"].dtype)}


def mamba_decode(cfg, p, x, state):
    """x [B,d] one step."""
    di, dt_rank, N = mamba_dims(cfg)
    xz = x @ p["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    xm_c, new_conv = _conv1d_step(xm, state["conv"].astype(xm.dtype),
                                  p["conv_w"], p["conv_b"])
    xm_c = jax.nn.silu(xm_c.astype(jnp.float32)).astype(x.dtype)
    dbc = xm_c @ p["x_proj"]
    dt_in, Bp, Cp = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus((dt_in @ p["dt_w"]).astype(jnp.float32)
                         + p["dt_b"].astype(jnp.float32))        # [B,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)                               # [B,di,N]
    b = (dt * xm_c.astype(jnp.float32))[..., None] * Bp.astype(jnp.float32)[:, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, Cp.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xm_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], {"h": h, "conv": new_conv}


def mamba_init_state(cfg, batch, dtype):
    di, _, N = mamba_dims(cfg)
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
    }


def mamba_state_spec(cfg, batch):
    di, _, N = mamba_dims(cfg)
    return {
        "h": P((batch, di, N), ("kv_batch", "inner", None)),
        "conv": P((batch, cfg.ssm.d_conv - 1, di), ("kv_batch", None, "inner")),
    }


# ===========================================================================
# mLSTM (xLSTM) — matrix memory with exponential gating
# ===========================================================================
def mlstm_dims(cfg):
    di = 2 * cfg.d_model
    H = cfg.n_heads
    dh = di // H
    return di, H, dh


def mlstm_specs(cfg) -> dict:
    d = cfg.d_model
    di, H, dh = mlstm_dims(cfg)
    K = 4  # short conv on the q/k path (xLSTM block)
    return {
        "norm": P((d,), ("embed",), init="ones"),
        "w_up": P((d, 2 * di), ("embed", "inner")),
        "conv_w": P((K, di), (None, "inner")),
        "conv_b": P((di,), ("inner",), init="zeros"),
        "wq": P((di, di), ("inner", "inner2")),
        "wk": P((di, di), ("inner", "inner2")),
        "wv": P((di, di), ("inner", "inner2")),
        "w_i": P((di, H), ("inner", "heads"), init="small"),
        "b_i": P((H,), ("heads",), init="zeros"),
        "w_f": P((di, H), ("inner", "heads"), init="small"),
        "b_f": P((H,), ("heads",), init="ones"),
        "gn": P((di,), ("inner",), init="ones"),
        "w_down": P((di, d), ("inner", "embed")),
    }


def _mlstm_chunk(q, k, v, logi, logf, state, eps=1e-6):
    """One chunk of stabilized chunkwise mLSTM.

    q/k/v [B,H,L,dh]; logi/logf [B,H,L]; state (C [B,H,dh,dh], n [B,H,dh],
    m [B,H]).  Returns (h [B,H,L,dh], new_state).
    """
    B, H, L, dh = q.shape
    C0, n0, m0 = state
    F = jnp.cumsum(logf, axis=-1)                     # [B,H,L] inclusive
    # decay matrix D[t,j] = F_t - F_j + logi_j for j<=t
    Dm = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri, Dm, NEG_INF)
    # stabilizer: max over intra contributions and the carried state
    m_intra = jnp.max(Dm, axis=-1)                    # [B,H,L]
    m_t = jnp.maximum(m_intra, F + m0[..., None])     # [B,H,L]
    d_intra = jnp.exp(Dm - m_t[..., None])            # [B,H,L,L]
    d_inter = jnp.exp(F + m0[..., None] - m_t)        # [B,H,L]

    qk = jnp.einsum("bhld,bhjd->bhlj", q, k) / (dh ** 0.5)
    w = qk * d_intra
    num = jnp.einsum("bhlj,bhjd->bhld", w, v)
    num = num + d_inter[..., None] * jnp.einsum("bhld,bhde->bhle", q, C0)
    # denominator: n_t · q_t with the same stabilization
    kq = jnp.sum(w, axis=-1)
    nq0 = d_inter * jnp.einsum("bhd,bhld->bhl", n0, q)
    den = kq + nq0
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # chunk-end state
    mL = jnp.maximum(F[..., -1] + m0, jnp.max(F[..., -1:] - F + logi, axis=-1))
    scale_old = jnp.exp(F[..., -1] + m0 - mL)         # [B,H]
    w_j = jnp.exp(F[..., -1:] - F + logi - mL[..., None])  # [B,H,L]
    C_new = scale_old[..., None, None] * C0 + jnp.einsum(
        "bhl,bhld,bhle->bhde", w_j, k / (dh ** 0.5), v)
    n_new = scale_old[..., None] * n0 + jnp.einsum("bhl,bhld->bhd", w_j, k / (dh ** 0.5))
    return h, (C_new, n_new, mL)


def mlstm_cell(q, k, v, logi, logf, state, chunk: int):
    """Full-sequence chunkwise mLSTM.  q/k/v [B,H,S,dh]."""
    B, H, S, dh = q.shape
    L = min(chunk, S)
    while S % L:
        L //= 2
    nc = S // L

    def to_chunks(x):
        return x.reshape(B, H, nc, L, *x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    def outer(st, xs):
        qc, kc, vc, ic, fc = xs
        h, st = _mlstm_chunk(qc, kc, vc, ic, fc, st)
        return st, h

    xs = (to_chunks(q), to_chunks(k), to_chunks(v), to_chunks(logi), to_chunks(logf))
    state, hs = jax.lax.scan(outer, state, xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return h, state


def mlstm_cell_sequential(q, k, v, logi, logf, state):
    """Step-by-step oracle for tests (identical math, O(S) scan)."""
    B, H, S, dh = q.shape

    def step(st, xs):
        qt, kt, vt, it, ft = xs
        C, n, m = st
        m_new = jnp.maximum(ft + m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(ft + m - m_new)
        kn = kt / (dh ** 0.5)
        C = fp[..., None, None] * C + ip[..., None, None] * kn[..., :, None] * vt[..., None, :]
        n = fp[..., None] * n + ip[..., None] * kn
        num = jnp.einsum("bhde,bhd->bhe", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3), v.transpose(2, 0, 1, 3),
          logi.transpose(2, 0, 1), logf.transpose(2, 0, 1))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 2, 0, 3), state


@jax.named_scope("mlstm")
def mlstm_forward(cfg, p, x, state=None):
    B, S, d = x.shape
    di, H, dh = mlstm_dims(cfg)
    if state is None:
        state = mlstm_init_state(cfg, B)
    xi = rms_norm(x, p["norm"], cfg.rms_eps)
    up = xi @ p["w_up"]
    xm, z = up[..., :di], up[..., di:]
    K = p["conv_w"].shape[0]
    xm_ext = jnp.concatenate([state["conv"].astype(xm.dtype), xm], axis=1)
    xc = _causal_conv1d(xm_ext, p["conv_w"], p["conv_b"])[:, K - 1:]
    new_conv = xm_ext[:, -(K - 1):]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = (xc @ p["wq"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (xc @ p["wk"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    v = (xm @ p["wv"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    logi = (xc @ p["w_i"] + p["b_i"]).astype(jnp.float32).transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(
        (xc @ p["w_f"] + p["b_f"]).astype(jnp.float32)).transpose(0, 2, 1)
    cell_state = (state["C"], state["n"], state["m"])
    h, cell_state = mlstm_cell(q.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), logi, logf, cell_state,
                               cfg.ssm.chunk if cfg.ssm else 256)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.rms_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = h @ p["w_down"]
    return out, {"C": cell_state[0], "n": cell_state[1], "m": cell_state[2],
                 "conv": new_conv.astype(state["conv"].dtype)}


def mlstm_decode(cfg, p, x, state):
    di, H, dh = mlstm_dims(cfg)
    B = x.shape[0]
    xi = rms_norm(x, p["norm"], cfg.rms_eps)
    up = xi @ p["w_up"]
    xm, z = up[..., :di], up[..., di:]
    xc, new_conv = _conv1d_step(xm, state["conv"].astype(xm.dtype),
                                p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    q = (xc @ p["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = (xc @ p["wk"]).reshape(B, H, dh).astype(jnp.float32) / (dh ** 0.5)
    v = (xm @ p["wv"]).reshape(B, H, dh).astype(jnp.float32)
    logi = (xc @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid((xc @ p["w_f"] + p["b_f"]).astype(jnp.float32))
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, logi)
    ip = jnp.exp(logi - m_new)
    fp = jnp.exp(logf + m - m_new)
    # rank-1 factorizable update (paper Sec. 5): C += i · k vᵀ
    C = fp[..., None, None] * C + ip[..., None, None] * k[..., :, None] * v[..., None, :]
    n = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhde,bhd->bhe", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, di)
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.rms_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_down"], {"C": C, "n": n, "m": m_new, "conv": new_conv}


def mlstm_init_state(cfg, batch):
    di, H, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),
    }


def mlstm_state_spec(cfg, batch):
    di, H, dh = mlstm_dims(cfg)
    return {
        "C": P((batch, H, dh, dh), ("kv_batch", None, "state_dim", None)),
        "n": P((batch, H, dh), ("kv_batch", None, "state_dim")),
        "m": P((batch, H), ("kv_batch", None)),
        "conv": P((batch, 3, di), ("kv_batch", None, "inner")),
    }


# ===========================================================================
# sLSTM — scalar memory, true recurrence (sequential)
# ===========================================================================
def slstm_dims(cfg):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def slstm_specs(cfg) -> dict:
    d = cfg.d_model
    H, dh = slstm_dims(cfg)
    return {
        "norm": P((d,), ("embed",), init="ones"),
        "W": P((d, 4 * d), ("embed", "inner")),
        "b": P((4 * d,), ("inner",), init="zeros"),
        "R": P((H, dh, 4 * dh), (None, "state_dim", None), init="small"),
        "gn": P((d,), ("embed",), init="ones"),
        "w_out": P((d, d), ("embed", "embed2")),
    }


def _slstm_step(cfg, p, st, xw):
    """xw [B, 4*d] (input projection of this step)."""
    H, dh = slstm_dims(cfg)
    c, n, h, m = st
    B = xw.shape[0]
    rec = jnp.einsum("bhd,hde->bhe", h, p["R"])           # [B,H,4*dh]
    gates = xw.reshape(B, H, 4 * dh) + rec
    zr, ir, fr, orr = jnp.split(gates, 4, axis=-1)        # [B,H,dh] each
    z = jnp.tanh(zr.astype(jnp.float32))
    o = jax.nn.sigmoid(orr.astype(jnp.float32))
    logi = ir.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fr.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, logi)
    ip = jnp.exp(logi - m_new)
    fp = jnp.exp(logf + m - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h_new = o * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new, m_new)


@jax.named_scope("slstm")
def slstm_forward(cfg, p, x, state=None):
    B, S, d = x.shape
    H, dh = slstm_dims(cfg)
    if state is None:
        state = slstm_init_state(cfg, B)
    xi = rms_norm(x, p["norm"], cfg.rms_eps)
    xw = xi @ p["W"] + p["b"]                              # [B,S,4d]

    def step(st, xt):
        st = _slstm_step(cfg, p, st, xt)
        return st, st[2]

    st0 = (state["c"], state["n"], state["h"], state["m"])
    st, hs = jax.lax.scan(step, st0, xw.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d)          # [B,S,H,dh]->[B,S,d]
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.rms_eps)
    out = h @ p["w_out"]
    return out, {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}


def slstm_decode(cfg, p, x, state):
    xi = rms_norm(x, p["norm"], cfg.rms_eps)
    xw = xi @ p["W"] + p["b"]
    st = _slstm_step(cfg, p, (state["c"], state["n"], state["h"], state["m"]), xw)
    B = x.shape[0]
    h = st[2].reshape(B, -1)
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.rms_eps)
    return h @ p["w_out"], {"c": st[0], "n": st[1], "h": st[2], "m": st[3]}


def slstm_init_state(cfg, batch):
    H, dh = slstm_dims(cfg)
    z = lambda: jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, H, dh), -1e30, jnp.float32)}


def slstm_state_spec(cfg, batch):
    H, dh = slstm_dims(cfg)
    mk = lambda: P((batch, H, dh), ("kv_batch", None, "state_dim"))
    return {"c": mk(), "n": mk(), "h": mk(), "m": mk()}
