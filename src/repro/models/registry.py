"""Model registry: one uniform API over all assigned architectures.

``build(cfg)`` returns a ``ModelAPI`` whose members are pure functions —
usable directly, under jit, or abstractly (dry-run via eval_shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

from . import encdec, lm
from .layers import P, abstract_from_spec, count_params, init_from_spec


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    specs: Any                                   # param spec tree (P leaves)
    init: Callable                               # (key, dtype?) -> params
    loss: Callable                               # (params, batch) -> (loss, metrics)
    prefill: Callable                            # (params, batch, cache_len?) -> (logits, cache)
    decode_step: Callable                        # (params, token, pos, cache) -> (logits, cache)
    cache_spec: Callable                         # (batch, seq) -> spec tree
    init_cache: Callable                         # (batch, seq, dtype) -> cache

    def n_params(self) -> int:
        return count_params(self.specs)

    def n_active_params(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.n_params()
        m = cfg.moe
        leaves = jax.tree.leaves_with_path(
            self.specs, is_leaf=lambda x: isinstance(x, P))
        total, routed = 0, 0
        for path, spec in leaves:
            n = 1
            for s in spec.shape:
                n *= s
            total += n
            keys = "/".join(str(getattr(k, "key", k)) for k in path)
            # routed expert tensors: stacked [*, E, d, f] under a "moe" node
            if "moe" in keys and m.n_experts in spec.shape and len(spec.shape) >= 3:
                routed += n
        return total - routed + int(routed * m.top_k / m.n_experts)


def build(cfg: ArchConfig) -> ModelAPI:
    if cfg.enc_dec:
        specs = encdec.encdec_specs(cfg)
        return ModelAPI(
            cfg=cfg,
            specs=specs,
            init=lambda key, dtype=None: init_from_spec(
                specs, key, dtype or jnp.dtype(cfg.param_dtype)),
            loss=lambda p, b: encdec.encdec_loss(cfg, p, b),
            prefill=lambda p, b, cache_len=None: encdec.encdec_prefill(
                cfg, p, b, cache_len),
            decode_step=lambda p, t, pos, c: encdec.encdec_decode(cfg, p, t, pos, c),
            cache_spec=lambda batch, seq: encdec.encdec_cache_spec(cfg, batch, seq),
            init_cache=lambda batch, seq, dtype: encdec.encdec_init_cache(
                cfg, batch, seq, dtype),
        )
    specs = lm.lm_specs(cfg)
    return ModelAPI(
        cfg=cfg,
        specs=specs,
        init=lambda key, dtype=None: init_from_spec(
            specs, key, dtype or jnp.dtype(cfg.param_dtype)),
        loss=lambda p, b: lm.lm_loss(cfg, p, b),
        prefill=lambda p, b, cache_len=None: lm.lm_prefill(cfg, p, b, cache_len),
        decode_step=lambda p, t, pos, c: lm.lm_decode(cfg, p, t, pos, c),
        cache_spec=lambda batch, seq: lm.lm_cache_spec(cfg, batch, seq),
        init_cache=lambda batch, seq, dtype: lm.lm_init_cache(cfg, batch, seq, dtype),
    )


# ---------------------------------------------------------------------------
# Batch input specs per workload shape (ShapeDtypeStruct factory)
# ---------------------------------------------------------------------------
def batch_spec(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Logical-axis specs for every model input of this workload cell."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        out = {
            "tokens": P((B, _text_len(cfg, S)), ("batch", "seq"), "zeros"),
            "labels": P((B, _text_len(cfg, S)), ("batch", "seq"), "zeros"),
        }
        if cfg.frontend == "vision":
            out["patches"] = P((B, cfg.n_frontend_tokens, d),
                               ("batch", "seq", None), "zeros")
        if cfg.enc_dec:
            out["frames"] = P((B, cfg.n_frontend_tokens, d),
                              ("batch", "seq", None), "zeros")
        return out
    if shape.kind == "prefill":
        out = {"tokens": P((B, _text_len(cfg, S)), ("batch", "seq"), "zeros")}
        if cfg.frontend == "vision":
            out["patches"] = P((B, cfg.n_frontend_tokens, d),
                               ("batch", "seq", None), "zeros")
        if cfg.enc_dec:
            out["frames"] = P((B, cfg.n_frontend_tokens, d),
                              ("batch", "seq", None), "zeros")
        return out
    # decode: one token + position; the cache is specced separately
    return {"token": P((B,), ("batch",), "zeros"),
            "pos": P((), (), "zeros")}


def _text_len(cfg: ArchConfig, seq_len: int) -> int:
    """VLM cells split seq_len into patch-prefix + text."""
    if cfg.frontend == "vision":
        return seq_len - cfg.n_frontend_tokens
    return seq_len


def abstract_batch(cfg, shape, spec_to_sharding=None) -> dict:
    specs = batch_spec(cfg, shape)
    out = {}
    for name, s in specs.items():
        dtype = jnp.int32 if name in ("tokens", "labels", "token", "pos") \
            else jnp.dtype(cfg.act_dtype)
        sh = spec_to_sharding(s) if spec_to_sharding is not None else None
        if sh is not None:
            out[name] = jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh)
        else:
            out[name] = jax.ShapeDtypeStruct(s.shape, dtype)
    return out


def real_batch(cfg, shape, key) -> dict:
    """Materialized random batch (smoke tests; reduced configs only)."""
    specs = batch_spec(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if name in ("tokens", "labels", "token"):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, jnp.int32)
        elif name == "pos":
            out[name] = jnp.zeros((), jnp.int32)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(
                jnp.dtype(cfg.act_dtype))
    return out
