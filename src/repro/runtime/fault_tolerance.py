"""Fault tolerance: supervised checkpoint-restart, straggler mitigation,
and elastic cluster membership.

What runs where:
  * ``Supervisor.run`` — the outer restart loop a real launcher wraps
    around the trainer: a step function that raises (preempted host, XLA
    error, NaN guard) triggers restore-from-latest-checkpoint and
    continuation, with exponential backoff and a restart budget.
  * ``StreamSupervisor.run`` — the same restart discipline specialized to
    the IVM stream executor: each attempt is ``executor.resume(stream)``
    (restore newest committed snapshot, replay from its offset), failures
    back off exponentially against a restart budget, and a non-finite
    guard rejects runs whose float view payloads picked up NaN/Inf
    (a poisoned ring value scatter-propagates through every later
    boundary snapshot — better to fail the run than persist it).
  * ``StragglerMonitor`` — per-step deadline tracking with EWMA baseline;
    on a real pod the action is re-dispatching the slow host's shard /
    alerting; here it records and exposes the decision.
  * ``ClusterState`` — heartbeat registry for elastic membership: nodes
    join/leave; ``plan_mesh`` recomputes the largest (data, model) mesh
    that fits the healthy node set, and the mesh-elastic checkpoints
    (checkpoint/checkpointer.py) let training resume on the new shape.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable


# ---------------------------------------------------------------------------
# Checkpoint-restart supervisor
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Supervisor:
    max_restarts: int = 3
    backoff_s: float = 0.1
    nan_is_failure: bool = True

    def run(self, *, n_steps: int, step_fn: Callable[[int], float],
            save_fn: Callable[[int], None], restore_fn: Callable[[], int],
            checkpoint_every: int = 10):
        """Drive ``step_fn(step) -> loss`` for n_steps with restart-on-
        failure.  ``restore_fn() -> step`` reloads the latest checkpoint.
        Returns (completed_steps, restarts, log)."""
        restarts = 0
        log: list[dict] = []
        step = restore_fn()
        while step < n_steps:
            try:
                loss = step_fn(step)
                if self.nan_is_failure and (loss != loss or math.isinf(loss)):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                log.append({"step": step, "loss": float(loss)})
                step += 1
                if step % checkpoint_every == 0:
                    save_fn(step)
            except Exception as e:  # noqa: BLE001 — restart path
                restarts += 1
                log.append({"step": step, "failure": repr(e)})
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"restart budget exhausted after {restarts - 1} restarts"
                    ) from e
                time.sleep(self.backoff_s * (2 ** (restarts - 1)))
                step = restore_fn()
        save_fn(step)
        return step, restarts, log


# ---------------------------------------------------------------------------
# Stream-level supervision (DESIGN.md §10)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StreamSupervisor:
    """Restart loop over ``StreamExecutor.resume``.

    Every attempt — including the first — goes through ``resume``: it
    establishes the offset-0 baseline snapshot before any update runs,
    so a failure at *any* later point (mid-segment, mid-admit,
    mid-checkpoint-write) restarts from a committed snapshot, never from
    a partially-advanced live engine.  Exceptions back off exponentially
    (``backoff_s * 2**(restarts-1)``) against ``max_restarts``; budget
    exhaustion re-raises chained to the last failure.  With
    ``nan_is_failure`` (default), a completed run whose float view
    payloads contain NaN/Inf is treated as failed *before* its final
    snapshot can be trusted.

    With ``escalate`` (default), repeated failures climb an escalation
    ladder instead of blindly retrying the same resume (DESIGN.md §11):

    1. **restart** — plain resume from the newest committed snapshot
       (handles transient faults: preemption, injected kills).
    2. **restore_previous_snapshot** — quarantine the newest snapshot
       and resume from the one before it (handles a *committed but
       poisoned* snapshot the checksum cannot catch, e.g. NaN payloads
       that were valid bytes when written).
    3. **quarantine_batch** — if the executor has an
       :class:`~repro.runtime.integrity.IntegrityConfig`, downgrade
       ``policy="strict"`` to ``"quarantine"`` so the offending updates
       are masked to dead letters instead of failing the run.
    4. **reevaluate_from_base** — restore the newest snapshot, recompute
       every view from stored base relations via the ``Reevaluate``
       interpreter (ground truth), re-commit the healed snapshot at the
       same offset, and resume.

    A rung that is not applicable (no checkpoint, only one snapshot, no
    integrity config, no stored base) falls back down the ladder; each
    log entry records the ``action`` taken."""

    max_restarts: int = 3
    backoff_s: float = 0.1
    nan_is_failure: bool = True
    escalate: bool = True

    #: escalation rungs, climbed on consecutive failures
    LADDER = ("restart", "restore_previous_snapshot", "quarantine_batch",
              "reevaluate_from_base")

    def run(self, executor, stream):
        """Drive ``executor.resume(stream)`` to completion.
        Returns (final_state, restarts, log)."""
        stream = list(stream)
        restarts = 0
        log: list[dict] = []
        while True:
            try:
                state = executor.resume(stream)
                if self.nan_is_failure:
                    self._check_finite(executor.engine)
                log.append({"restarts": restarts, "ok": True})
                return state, restarts, log
            except Exception as e:  # noqa: BLE001 — restart path
                restarts += 1
                if restarts > self.max_restarts:
                    log.append({"restarts": restarts, "failure": repr(e)})
                    raise RuntimeError(
                        f"restart budget exhausted after {restarts - 1} "
                        "restarts") from e
                action = (self._escalation(executor, e, restarts)
                          if self.escalate else "restart")
                log.append({"restarts": restarts, "failure": repr(e),
                            "action": action})
                time.sleep(self.backoff_s * (2 ** (restarts - 1)))

    # -------------------------------------------------------- escalation
    def _escalation(self, executor, error, restarts: int) -> str:
        """Pick and *apply* the recovery rung for this failure; the next
        loop iteration's ``resume`` then runs against the mutated state
        (quarantined snapshot, relaxed policy, healed checkpoint)."""
        from repro.runtime import integrity as integrity_mod

        cfg = getattr(executor, "integrity", None)
        if isinstance(error, integrity_mod.StreamIntegrityError):
            # an integrity failure will deterministically recur on plain
            # restart — jump straight to a rung that changes something
            if cfg is not None and cfg.policy == "strict":
                cfg.policy = "quarantine"
                return "quarantine_batch"
            return self._reevaluate(executor)
        rung = self.LADDER[min(restarts - 1, len(self.LADDER) - 1)]
        if rung == "restore_previous_snapshot":
            ck = getattr(executor, "checkpoint", None)
            steps = ck.ckpt.all_steps() if ck is not None else []
            if len(steps) > 1:
                ck.ckpt.discard_pending()
                ck.ckpt.quarantine_step(steps[-1])
                return "restore_previous_snapshot"
            return "restart"  # nothing older to fall back to
        if rung == "quarantine_batch":
            if cfg is not None and cfg.policy == "strict":
                cfg.policy = "quarantine"
                return "quarantine_batch"
            return self._reevaluate(executor)
        if rung == "reevaluate_from_base":
            return self._reevaluate(executor)
        return "restart"

    @staticmethod
    def _reevaluate(executor) -> str:
        """Last rung: heal the newest snapshot by recomputing every view
        from stored base relations, re-commit it at the same offset, and
        let the next resume pick it up.  Falls back to plain restart when
        the executor has no checkpoint or no stored base."""
        from repro.runtime import integrity as integrity_mod

        ck = getattr(executor, "checkpoint", None)
        engine = getattr(executor, "engine", None)
        if ck is None or engine is None:
            return "restart"
        try:
            ck.ckpt.discard_pending()
            meta = ck.restore_into(engine)
            if meta is None:
                return "restart"
            integrity_mod.reevaluate_from_base(engine)
            ck.save_boundary(engine, offset=int(meta["offset"]),
                             segment=int(meta.get("segment", -1)),
                             blocking=True)
            return "reevaluate_from_base"
        except integrity_mod.StreamIntegrityError:
            return "restart"  # no stored base relations to recompute from

    @staticmethod
    def _check_finite(engine) -> None:
        """Raise FloatingPointError if any float view payload is
        non-finite (the float-ring analogue of the trainer's NaN-loss
        guard; integer rings vacuously pass)."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        for name, view in engine.views.items():
            for leaf in jax.tree.leaves(view):
                if not jnp.issubdtype(jnp.asarray(leaf).dtype,
                                      jnp.floating):
                    continue
                if not bool(np.asarray(jnp.all(jnp.isfinite(leaf)))):
                    raise FloatingPointError(
                        f"non-finite payload in view {name!r}")


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time baseline; flags steps slower than factor× baseline.
    On a TPU pod the mitigation is re-dispatch / hot-spare swap of the slow
    host; the monitor's verdicts drive that decision."""

    factor: float = 3.0
    alpha: float = 0.1
    _ewma: float | None = None
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self._ewma is not None and dt > self.factor * self._ewma:
            is_straggler = True
            self.events.append({"step": step, "dt": dt, "baseline": self._ewma})
        else:
            # stragglers are excluded from the baseline update
            self._ewma = dt if self._ewma is None else (
                (1 - self.alpha) * self._ewma + self.alpha * dt)
        return is_straggler

    @property
    def baseline(self) -> float | None:
        return self._ewma


# ---------------------------------------------------------------------------
# Elastic membership
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Node:
    node_id: str
    n_chips: int
    last_heartbeat: float


class ClusterState:
    """Heartbeat registry + elastic mesh planning."""

    def __init__(self, heartbeat_timeout_s: float = 30.0):
        self.timeout = heartbeat_timeout_s
        self.nodes: dict[str, Node] = {}

    def heartbeat(self, node_id: str, n_chips: int = 4,
                  now: float | None = None) -> None:
        now = time.time() if now is None else now
        self.nodes[node_id] = Node(node_id, n_chips, now)

    def healthy(self, now: float | None = None) -> list[Node]:
        now = time.time() if now is None else now
        return [n for n in self.nodes.values()
                if now - n.last_heartbeat <= self.timeout]

    def healthy_chips(self, now: float | None = None) -> int:
        return sum(n.n_chips for n in self.healthy(now))

    def plan_mesh(self, *, model_parallel: int = 16,
                  now: float | None = None) -> tuple[int, int]:
        """Largest (data, model) mesh shape over healthy chips: model axis
        fixed (TP degree is a model property), data axis = largest power of
        two of remaining chips.  Returns (data, model)."""
        chips = self.healthy_chips(now)
        data = chips // model_parallel
        if data < 1:
            raise RuntimeError(
                f"{chips} healthy chips cannot host model_parallel={model_parallel}")
        data_pow2 = 2 ** int(math.floor(math.log2(data)))
        return (data_pow2, model_parallel)
