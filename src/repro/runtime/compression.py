"""Rank-r gradient compression with error feedback (PowerSGD-style).

This is the paper's lock #2 — *factorizable updates* — applied to
data-parallel gradient synchronization: instead of all-reducing a dense
[n, m] gradient, each worker all-reduces the factors of a rank-r
decomposition G ≈ P Qᵀ (n·r + m·r values instead of n·m).  Exactly the
Sec. 5 economics: "the cumulative size of the decomposition relations can
be much less than the size of the original delta relation".

Error feedback keeps the compression unbiased over time: the residual
G - P Qᵀ is added to the next step's gradient before compressing.

Under jit+GSPMD the all-reduce is implicit (gradients of replicated
params); this module provides the *compression operator* and a wrapper
that turns any Optimizer into a compressed-sync optimizer (used by the
trainer and benchmarked in benchmarks/bench_grad_compression.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 4
    min_size: int = 4096          # don't compress small tensors
    power_iters: int = 1


def _orthonormalize(m: jnp.ndarray) -> jnp.ndarray:
    q, _ = jnp.linalg.qr(m)
    return q


def compress_decompress(g: jnp.ndarray, err: jnp.ndarray, q_prev: jnp.ndarray,
                        cfg: CompressionConfig):
    """One PowerSGD round on a single [n, m] gradient.

    Returns (g_hat, new_err, new_q).  In a multi-host run the all-reduce
    happens on P and Q (the factors); here the factors ARE the synced
    payload — the caller's mean over DP is mathematically the mean of
    P Qᵀ since Q is fixed across workers after orthonormalization.
    """
    n, m = g.shape
    gf = g.astype(jnp.float32) + err
    q = q_prev
    for _ in range(cfg.power_iters):
        p = gf @ q                      # [n, r]   (all-reduced in DP sync)
        p = _orthonormalize(p)
        q = gf.T @ p                    # [m, r]   (all-reduced in DP sync)
    g_hat = p @ q.T
    new_err = gf - g_hat                # error feedback
    return g_hat.astype(g.dtype), new_err, q


def init_compression_state(params, cfg: CompressionConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(17)

    def slot(p):
        if p.ndim == 2 and p.size >= cfg.min_size:
            k = jax.random.fold_in(key, p.size)
            q = jax.random.normal(k, (p.shape[1], cfg.rank), jnp.float32)
            return {"err": jnp.zeros(p.shape, jnp.float32),
                    "q": _orthonormalize(q)}
        return None

    return jax.tree.map(slot, params)


def compress_grads(grads, state, cfg: CompressionConfig):
    """Apply rank-r compression+error feedback leafwise; non-2D or small
    leaves pass through untouched."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    for g, s in zip(flat_g, flat_s):
        if s is None:
            out_g.append(g)
            out_s.append(None)
        else:
            gh, err, q = compress_decompress(g, s["err"], s["q"], cfg)
            out_g.append(gh)
            out_s.append({"err": err, "q": q})
    return treedef.unflatten(out_g), treedef.unflatten(out_s)


def compression_ratio(params, cfg: CompressionConfig) -> float:
    """Synced bytes with compression / without (the Sec. 5 size economics)."""
    dense = 0
    comp = 0
    for p in jax.tree.leaves(params):
        dense += p.size
        if p.ndim == 2 and p.size >= cfg.min_size:
            comp += (p.shape[0] + p.shape[1]) * cfg.rank
        else:
            comp += p.size
    return comp / max(dense, 1)


def compressed_optimizer(base: Optimizer, params, cfg: CompressionConfig) -> Optimizer:
    """Wrap an optimizer so updates see compressed gradients; the
    compression state (error feedback + power-iteration vectors) rides in
    the optimizer state."""

    def init(p):
        return {"base": base.init(p), "comp": init_compression_state(p, cfg)}

    def update(p, state, grads, step=None):
        grads_c, comp = compress_grads(grads, state["comp"], cfg)
        new_p, new_base = base.update(p, state["base"], grads_c, step)
        return new_p, {"base": new_base, "comp": comp}

    return Optimizer(init, update, name=f"{base.name}+powersgd{cfg.rank}")
