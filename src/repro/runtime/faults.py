"""Deterministic fault injection for chaos-testing the durable stream path.

The executor (and the checkpointer's writer) call ``faults.crossing(point)``
at named execution points; when no plan is installed this is a dict lookup
and return — cheap enough to leave in production code paths.  A test
installs a :class:`FaultPlan` to kill execution at exactly the N-th
crossing of a point, either by raising :class:`InjectedFault` (in-process
recovery tests) or by ``SIGKILL``-ing the process (subprocess chaos tests:
no ``atexit``, no ``finally`` — the same torn state a preempted worker or
an OOM kill leaves behind).

Injection points wired into the stream executor / checkpointer:

====================================  =========================================
point                                 fires
====================================  =========================================
``mid_segment``                       after a segment's dispatch, before its
                                      boundary checkpoint commits
``mid_admit``                         at the top of segment admission, before
                                      any rehash/prepare work
``post_rehash_pre_recompile``         after sparse tables were rehashed to the
                                      segment's grown capacities but before
                                      the new plans compile — the engine's
                                      storage signature has already changed
``mid_checkpoint_write``              inside ``Checkpointer._write`` after the
                                      tmp dir is fully written but before the
                                      atomic rename (commit)
``snapshot_committed``                inside ``Checkpointer._write`` right
                                      after the atomic rename — the snapshot
                                      is durable; ``mode="bitflip"`` corrupts
                                      it in place (silent media corruption)
====================================  =========================================

Determinism: ``FaultPlan(point, at=k)`` fires on the k-th crossing
(0-based) of ``point`` and only once — after firing, the plan is spent and
execution (on the resumed process) runs clean.  Crossing counters survive
the fire so tests can assert how far execution got.

Besides ``raise``/``kill9`` there is a third mode, ``bitflip``: instead of
stopping execution it flips one bit of the file named by the crossing's
``path`` context and lets execution continue — modelling silent storage
corruption (a torn sector, a cosmic-ray bit) that only snapshot
checksums (DESIGN.md §11) can catch.
"""
from __future__ import annotations

import os
import signal
from contextlib import contextmanager
from dataclasses import dataclass, field


class InjectedFault(RuntimeError):
    """Raised by an in-process fault crossing; never raised organically."""


#: the valid ``FaultPlan.point`` values — kept in one place so a typo'd
#: point name fails fast at install time instead of silently never firing
POINTS = (
    "mid_segment",
    "mid_admit",
    "post_rehash_pre_recompile",
    "mid_checkpoint_write",
    "snapshot_committed",
)


@dataclass
class FaultPlan:
    point: str          # one of POINTS
    at: int = 0         # fire on the at-th crossing of `point` (0-based)
    mode: str = "raise"  # "raise" | "kill9" | "bitflip" (corrupt & continue)

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(f"unknown fault point {self.point!r}; "
                             f"expected one of {POINTS}")
        if self.mode not in ("raise", "kill9", "bitflip"):
            raise ValueError(f"unknown fault mode {self.mode!r}")


def _flip_bit(path: str) -> None:
    """Flip the top bit of the last byte of ``path`` in place."""
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size == 0:
            return
        f.seek(size - 1)
        byte = f.read(1)[0]
        f.seek(size - 1)
        f.write(bytes([byte ^ 0x80]))


@dataclass
class FaultInjector:
    plan: FaultPlan | None = None
    counts: dict = field(default_factory=dict)   # point -> crossings seen
    fired: list = field(default_factory=list)    # (point, index, ctx) log

    def crossing(self, point: str, **ctx) -> None:
        n = self.counts.get(point, 0)
        self.counts[point] = n + 1
        plan = self.plan
        if plan is None or plan.point != point or plan.at != n:
            return
        self.plan = None  # spent: the resumed/retried path runs clean
        self.fired.append((point, n, ctx))
        if plan.mode == "kill9":
            os.kill(os.getpid(), signal.SIGKILL)
        if plan.mode == "bitflip":
            # silent corruption: damage the crossing's file and let
            # execution continue — only checksum verification can tell
            _flip_bit(ctx["path"])
            return
        raise InjectedFault(f"injected fault at {point}[{n}] ({ctx})")


_active = FaultInjector()


def injector() -> FaultInjector:
    return _active


def install(plan: FaultPlan | None) -> FaultInjector:
    """Arm ``plan`` (or disarm with None) and reset counters/fired log."""
    global _active
    _active = FaultInjector(plan=plan)
    return _active


def clear() -> None:
    install(None)


@contextmanager
def inject(point: str, at: int = 0, mode: str = "raise"):
    """``with faults.inject("mid_segment", at=1): ...`` — arms a plan for
    the body and always disarms on exit, yielding the injector for
    post-mortem assertions on ``counts``/``fired``."""
    inj = install(FaultPlan(point=point, at=at, mode=mode))
    try:
        yield inj
    finally:
        clear()


def crossing(point: str, **ctx) -> None:
    """The production-side hook: no-op unless a plan is armed on this
    exact point/index."""
    _active.crossing(point, **ctx)
