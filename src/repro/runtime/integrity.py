"""Runtime integrity layer: validated admission, quarantine, and audited
Reevaluate self-healing (DESIGN.md §11).

PR 6 made the stream executor recoverable from fail-stop crashes; this
module makes the *state* trustworthy under bad data and silent corruption.
F-IVM's view hierarchy gives the layer a cheap ground truth — every
materialized view is recomputable from the stored base relations (the
"higher-order views as insurance" property of Nikolic & Olteanu 2017) —
so integrity decomposes into four pillars:

1. **Validated admission** (:func:`admit_stream`): per-batch checks at
   segment-admission time — finite payloads, in-domain keys, schema/dtype
   conformance — under three policies.  ``strict`` raises
   :class:`StreamIntegrityError` before the offending segment runs (and
   therefore before any poisoned boundary snapshot can commit);
   ``quarantine`` masks offending tuples out of the batch (key 0 +
   ring-zero payload: exactly the executor's padding convention, so a
   masked row is bit-transparent) and routes them to a
   :class:`DeadLetterLog` with reason codes; ``permissive`` skips
   validation.  The row checks themselves are one jit-compiled function
   (:func:`validate_rows`); admission pays a single host sync per segment
   for the per-batch violation flags.

2. **Checksummed snapshots**: per-leaf CRC32 fingerprints written into
   the checkpoint manifest and verified on restore — the detection side
   lives in ``repro.checkpoint.checkpointer`` (``ChecksumError``), proven
   by the ``snapshot_committed`` bit-flip fault point in
   ``repro.runtime.faults``.

3. **Drift-bounded reconciliation** (:func:`audit_engine`): every
   ``audit_interval`` segment boundaries the audited views are recomputed
   from base relations via the plan IR's ``Reevaluate`` interpretation
   (``plan.reevaluate_store``) and compared against the live incremental
   state.  Integer rings must match exactly (any divergence is
   corruption, not numerics, and raises); float rings are allowed
   bounded replay drift — divergence beyond ``audit_tol`` is repaired in
   place by swapping in the recomputed view (capacity-preserving for
   sparse storage, so compiled segment programs stay valid).  Divergence
   magnitude lands in ``audit_log`` as telemetry either way.

4. **Graceful degradation**: capacity pressure on the segmented path
   downgrades to emergency re-segmentation (split + rehash) or an eager
   per-batch spill instead of a hard :class:`StreamCapacityError` — the
   mechanics live in ``repro.core.stream`` and record their decisions in
   ``degrade_log``; ``StreamSupervisor`` (repro.runtime.fault_tolerance)
   adds the escalation ladder on top, with
   :func:`reevaluate_from_base` as its strongest rung.

This module deliberately avoids importing ``repro.core.stream`` at module
scope (the executor imports *us* lazily; keeping the edge one-directional
avoids an import cycle through ``repro.core``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core import storage as storage_mod
from repro.core.relations import COOUpdate

# --------------------------------------------------------------------------
# Reason codes (dead-letter vocabulary)
# --------------------------------------------------------------------------
REASON_NONFINITE = "nonfinite_payload"
REASON_KEY_DOMAIN = "key_out_of_domain"
REASON_SCHEMA = "schema_mismatch"
REASON_DTYPE = "dtype_mismatch"

#: bit positions of the jit-side row validator (:func:`validate_rows`)
_BIT_REASONS = ((1, REASON_NONFINITE), (2, REASON_KEY_DOMAIN))

POLICIES = ("strict", "quarantine", "permissive")


class StreamIntegrityError(RuntimeError):
    """An integrity invariant failed: poisoned admission under ``strict``,
    integer-ring audit divergence, or an audit that cannot run (no stored
    base).  Carries the offending :class:`DeadLetter` records when the
    failure is data-shaped."""

    def __init__(self, msg: str, records=()):
        super().__init__(msg)
        self.records = tuple(records)


@dataclasses.dataclass(frozen=True)
class DeadLetter:
    """One quarantined tuple (or whole batch, ``row == -1``)."""

    rel: str
    stream_index: int  # absolute update index in the run's stream
    row: int  # row within the batch; -1 = the whole batch
    key: tuple  # the offending key (empty for whole-batch records)
    reasons: tuple[str, ...]  # reason codes, see REASON_*


class DeadLetterLog:
    """Host-side sink for quarantined tuples.

    Bounded (``max_records``): past the cap only the drop counter grows,
    so a hostile stream cannot OOM the host through its own rejects."""

    def __init__(self, max_records: int = 10_000):
        self.max_records = max_records
        self.records: list[DeadLetter] = []
        self.dropped = 0

    def append(self, rec: DeadLetter) -> None:
        if len(self.records) < self.max_records:
            self.records.append(rec)
        else:
            self.dropped += 1

    def counts(self) -> dict[str, int]:
        """Quarantined-record count per reason code."""
        out: dict[str, int] = {}
        for rec in self.records:
            for r in rec.reasons:
                out[r] = out.get(r, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.records) + self.dropped

    def __iter__(self):
        return iter(self.records)

    def clear(self) -> None:
        self.records.clear()
        self.dropped = 0


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------
@dataclasses.dataclass
class IntegrityConfig:
    """Integrity policy + telemetry attached to a ``StreamExecutor``.

    ``policy`` governs admission validation; ``audit_interval`` enables
    the audited Reevaluate pass every k segment boundaries (requires the
    engine to store its base relations — ``IVMEngine.build(...,
    store_base=True)``); ``segment_updates`` caps segment length the same
    way the checkpointer's knob does, so validation/audit boundaries
    exist even on streams capacity segmentation would never split;
    ``capacity_degrade`` turns :class:`StreamCapacityError` hard fails
    into emergency re-segmentation / eager spill."""

    policy: str = "quarantine"
    audit_interval: int | None = None
    audit_views: tuple[str, ...] | None = None  # None -> the root view
    audit_tol: float = 1e-5
    audit_repair: bool = True
    segment_updates: int | None = None
    capacity_degrade: bool = True
    dead_letters: DeadLetterLog = dataclasses.field(
        default_factory=DeadLetterLog)
    audit_log: list = dataclasses.field(default_factory=list)
    degrade_log: list = dataclasses.field(default_factory=list)
    #: quarantine-mode validation results awaiting their host readback —
    #: (stream index, rel, original update, device reason bits).  Drained
    #: by :func:`flush_dead_letters`; never touched under ``strict``.
    pending_dead_letters: list = dataclasses.field(
        default_factory=list, repr=False)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if self.audit_interval is not None and self.audit_interval < 1:
            raise ValueError("audit_interval must be >= 1")
        if self.segment_updates is not None and self.segment_updates < 1:
            raise ValueError("segment_updates must be >= 1")

    @property
    def active(self) -> bool:
        """Whether the executor must take the segmented path for this
        config to observe anything."""
        return (self.policy != "permissive"
                or self.audit_interval is not None
                or self.segment_updates is not None)

    def audit_due(self, segment: int) -> bool:
        """Audit at every ``audit_interval``-th boundary (segment is the
        0-based index; the first audit lands after segment k-1)."""
        k = self.audit_interval
        return k is not None and (segment + 1) % k == 0


# --------------------------------------------------------------------------
# Pillar 1 — validated admission
# --------------------------------------------------------------------------
def _row_bits(keys: jnp.ndarray, payload_leaves: tuple,
              domains: tuple[int, ...]) -> jnp.ndarray:
    B = keys.shape[0]
    bad_pay = jnp.zeros((B,), jnp.bool_)
    for leaf in payload_leaves:
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            finite = jnp.isfinite(leaf).reshape(B, -1).all(axis=1)
            bad_pay = bad_pay | ~finite
    doms = jnp.asarray(domains, keys.dtype).reshape(1, -1)
    bad_key = jnp.any((keys < 0) | (keys >= doms), axis=1)
    return bad_pay.astype(jnp.int32) + 2 * bad_key.astype(jnp.int32)


@functools.partial(jax.jit, static_argnums=(2,))
def validate_rows(keys: jnp.ndarray, payload_leaves: tuple,
                  domains: tuple[int, ...]) -> jnp.ndarray:
    """Per-row reason bits for one COO batch — pure jnp, jit-compiled
    once per (batch, schema) shape: bit 1 = non-finite payload in any
    ring component, bit 2 = key outside ``[0, domain)`` in any column.
    Integer payload leaves are vacuously finite and skipped."""
    return _row_bits(keys, payload_leaves, domains)


@functools.partial(jax.jit, static_argnums=(3,))
def _validate_sanitize(keys: jnp.ndarray, payload_leaves: tuple,
                       zero_leaves: tuple,
                       domains: tuple[int, ...]):
    """Fused validate + sanitize — the quarantine hot path.  One jitted
    dispatch per batch (instead of a validate call plus several eager
    masking ops): returns the reason bits alongside the masked keys and
    payload leaves, which are the identity when no bits are set."""
    bits = _row_bits(keys, payload_leaves, domains)
    bad = bits > 0
    keys_s = jnp.where(bad[:, None], 0, keys)
    leaves_s = tuple(
        jnp.where(bad.reshape((-1,) + (1,) * (x.ndim - 1)), z, x)
        for x, z in zip(payload_leaves, zero_leaves))
    return bits, keys_s, leaves_s


#: per-(ring, batch) cache of ring-zero payload trees, so the quarantine
#: admission path does not re-dispatch ``ring.zeros`` for every batch
_ZERO_CACHE: dict = {}


def _zero_payload(ring, batch: int):
    key = (id(ring), int(batch))
    zero = _ZERO_CACHE.get(key)
    if zero is None:
        zero = _ZERO_CACHE[key] = ring.zeros((int(batch),))
    return zero


def reasons_of(bits: int) -> tuple[str, ...]:
    """Decode a row's reason bits into reason codes."""
    return tuple(code for bit, code in _BIT_REASONS if bits & bit)


def sanitize_batch(upd: COOUpdate, reason_bits: jnp.ndarray,
                   ring) -> COOUpdate:
    """Mask offending rows transparent: key 0 + ring-zero payload — the
    executor's padding convention, so scatter-⊎ and indicator transition
    gating both treat the row as a no-op.  Pure jnp (jit-compatible)."""
    bad = reason_bits > 0
    keys = jnp.where(bad[:, None], 0, upd.keys)
    zero = ring.zeros((upd.batch,))
    payload = jax.tree.map(
        lambda x, z: jnp.where(bad.reshape((-1,) + (1,) * (x.ndim - 1)),
                               z, x),
        upd.payload, zero)
    return COOUpdate(upd.schema, keys, payload)


def batch_schema_errors(query, rel: str, upd) -> tuple[str, ...]:
    """Host-side static conformance of one batch against the declared
    relation: schema tuple, key arity/dtype, payload leaf dtypes.  These
    are whole-batch defects — no per-row mask can fix a wrong shape."""
    errs: list[str] = []
    declared = tuple(query.relations[rel])
    if not isinstance(upd, COOUpdate):
        return (REASON_SCHEMA,)
    if tuple(upd.schema) != declared:
        errs.append(REASON_SCHEMA)
    elif upd.keys.ndim != 2 or upd.keys.shape[1] != len(declared):
        errs.append(REASON_SCHEMA)
    if not jnp.issubdtype(jnp.asarray(upd.keys).dtype, jnp.integer):
        errs.append(REASON_DTYPE)
    ring = query.ring
    want = jnp.dtype(ring.dtype)
    for leaf in jax.tree.leaves(upd.payload):
        if jnp.dtype(jnp.asarray(leaf).dtype) != want:
            errs.append(REASON_DTYPE)
            break
    return tuple(errs)


def _transparent_batch(query, rel: str, batch: int) -> COOUpdate:
    """An all-padding replacement batch (whole-batch quarantine)."""
    ring = query.ring
    k = len(query.relations[rel])
    return COOUpdate(tuple(query.relations[rel]),
                     jnp.zeros((max(batch, 1), k), jnp.int32),
                     ring.zeros((max(batch, 1),)))


def _batch_dead_letters(rel: str, index: int, upd, bits) -> list:
    """Host readback of one flagged batch's offending rows (blocks on
    ``bits``)."""
    bits_h = np.asarray(bits)
    keys_h = np.asarray(upd.keys)
    return [
        DeadLetter(rel, index, int(r),
                   tuple(int(k) for k in keys_h[r]),
                   reasons_of(int(bits_h[r])))
        for r in np.nonzero(bits_h)[0]
    ]


def admit_stream(engine, sub_stream, cfg: IntegrityConfig,
                 base_offset: int = 0):
    """Validated admission of one segment's updates.

    Returns the sub-stream with offending rows/batches masked out
    (``quarantine``), raises :class:`StreamIntegrityError` carrying the
    offending records (``strict``), or passes through (``permissive``).
    Row checks run jit-compiled on device.  Under ``quarantine`` the
    whole admission is *sync-free*: every checked batch is sanitized
    lazily on device (``sanitize_batch`` is the identity when its reason
    bits are all zero), and the host readback that turns flagged rows
    into dead letters is parked on ``cfg.pending_dead_letters`` for
    :func:`flush_dead_letters` — syncing here would stall the segment
    pipeline behind the previous segment's in-flight execution.
    ``strict`` must sync: the contract is that a poisoned update fails
    admission *before* its segment can run or snapshot, so it pays one
    stacked host read per segment.  Replay-deterministic: resuming a run
    re-admits the same raw updates and masks them the same way (dead
    letters may be re-recorded across restarts)."""
    if cfg is None or cfg.policy == "permissive":
        return list(sub_stream)
    query = engine.query
    ring = query.ring
    out: list = []
    checks: list = []  # (position, rel, upd, reason_bits)
    for j, (rel, upd) in enumerate(sub_stream):
        errs = batch_schema_errors(query, rel, upd)
        if errs:
            rec = DeadLetter(rel, base_offset + j, -1, (), errs)
            if cfg.policy == "strict":
                raise StreamIntegrityError(
                    f"update {base_offset + j} ({rel}) rejected at "
                    f"admission: {', '.join(errs)}", [rec])
            cfg.dead_letters.append(rec)
            out.append((rel, _transparent_batch(query, rel,
                                                getattr(upd, "batch", 1))))
            continue
        doms = tuple(int(query.domains[v]) for v in upd.schema)
        leaves = tuple(jax.tree.leaves(upd.payload))
        if cfg.policy == "quarantine":
            zero = _zero_payload(ring, upd.batch)
            bits, keys_s, leaves_s = _validate_sanitize(
                upd.keys, leaves, tuple(jax.tree.leaves(zero)), doms)
            payload_s = jax.tree.unflatten(
                jax.tree.structure(upd.payload), leaves_s)
            out.append((rel, COOUpdate(upd.schema, keys_s, payload_s)))
        else:
            bits = validate_rows(upd.keys, leaves, doms)
            out.append((rel, upd))
        checks.append((j, rel, upd, bits))
    if not checks:
        return out
    if cfg.policy == "quarantine":
        cfg.pending_dead_letters.extend(
            (base_offset + j, rel, upd, bits)
            for j, rel, upd, bits in checks)
        return out
    # strict: one stacked host sync, before anything can run or snapshot
    flags = np.asarray(jnp.stack([jnp.any(b > 0) for _, _, _, b in checks]))
    for (j, rel, upd, bits), flagged in zip(checks, flags):
        if not flagged:
            continue
        records = _batch_dead_letters(rel, base_offset + j, upd, bits)
        raise StreamIntegrityError(
            f"update {base_offset + j} ({rel}) rejected at admission: "
            f"{len(records)} offending row(s) — "
            + ", ".join(sorted({c for rec in records
                                for c in rec.reasons})), records)
    return out


def flush_dead_letters(cfg: IntegrityConfig | None) -> int:
    """Drain ``cfg.pending_dead_letters`` into the dead-letter log: one
    stacked host sync for the per-batch violation flags, then a row
    readback for flagged batches only.  Called by the executor once the
    admitted segments have executed (the flags are ready — the sync is
    then free); returns the number of dead letters recorded."""
    if cfg is None or not cfg.pending_dead_letters:
        return 0
    pending, cfg.pending_dead_letters = cfg.pending_dead_letters, []
    flags = np.asarray(jnp.stack([jnp.any(b > 0)
                                  for _, _, _, b in pending]))
    n = 0
    for (idx, rel, upd, bits), flagged in zip(pending, flags):
        if not flagged:
            continue
        for rec in _batch_dead_letters(rel, idx, upd, bits):
            cfg.dead_letters.append(rec)
            n += 1
    return n


# --------------------------------------------------------------------------
# Pillar 3 — audited Reevaluate (drift-bounded reconciliation)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AuditRecord:
    """Outcome of auditing one view at one segment boundary."""

    segment: int
    view: str
    exact: bool  # bit-identical to the from-base recomputation
    max_abs_err: float
    repaired: bool
    wall_s: float


def reference_store(engine) -> dict:
    """Recompute every view from the stored base relations via the plan
    IR's ``Reevaluate`` interpretation.  The audit's ground truth — and
    only available when the engine stores all base relations."""
    missing = sorted(set(engine.query.relations) - set(engine.base))
    if missing:
        raise StreamIntegrityError(
            f"audited Reevaluate needs stored base relations (missing "
            f"{missing}); build the engine with store_base=True")
    return plan_mod.reevaluate_store(engine, engine.base)


def _repair_capacity(live, active: int) -> int:
    """Capacity for a repaired sparse view: keep the live capacity (so
    compiled segment programs and shard placements stay valid) unless the
    recomputed active set could not fit under the load factor."""
    cap = live.capacity
    while active > storage_mod.LOAD_FACTOR * cap:
        cap *= 2
    return cap


def repair_view(engine, name: str, ref_dense) -> None:
    """Swap the recomputed view in under the live storage backend."""
    live = engine.views[name]
    if isinstance(live, storage_mod.SparseRelation):
        ring = ref_dense.ring
        active = int(np.asarray(jnp.sum(~ring.is_zero(ref_dense.payload))))
        engine.views[name] = storage_mod.SparseRelation.from_dense(
            ref_dense, capacity=_repair_capacity(live, active))
    else:
        engine.views[name] = ref_dense


def audit_engine(engine, cfg: IntegrityConfig,
                 segment: int = -1) -> list[AuditRecord]:
    """One audited Reevaluate pass: recompute the audited views from base
    relations, compare against the live incremental state, and repair
    divergence.

    Integer rings must be exact — any mismatch is corruption (incremental
    maintenance over an exact ring cannot drift) and raises
    :class:`StreamIntegrityError`.  Float rings tolerate replay drift up
    to ``audit_tol`` (relative, floored at 1): beyond it the live view is
    replaced by the recomputation (``audit_repair``).  Every pass appends
    divergence telemetry to ``cfg.audit_log``.  Host-synchronous by
    construction (it compares device values) — the executor runs it at
    segment boundaries, priced by the BENCH_stream integrity leg."""
    t0 = time.perf_counter()
    store = reference_store(engine)
    names = cfg.audit_views if cfg.audit_views else (engine.tree.name,)
    records: list[AuditRecord] = []
    for name in names:
        ref_dense = storage_mod.as_dense(store[name])
        live_dense = storage_mod.as_dense(engine.views[name])
        is_float = jnp.issubdtype(jnp.dtype(ref_dense.ring.dtype),
                                  jnp.floating)
        max_abs = 0.0
        max_scaled = 0.0
        for c in ref_dense.ring.components:
            ref = jnp.asarray(ref_dense.payload[c])
            live = jnp.asarray(live_dense.payload[c]).astype(ref.dtype)
            diff = jnp.abs(live - ref)
            # NaN in the live view counts as infinite divergence
            diff = jnp.where(jnp.isnan(live - ref), jnp.inf, diff) \
                if is_float else diff
            max_abs = max(max_abs, float(np.asarray(jnp.max(diff))))
            scale = jnp.maximum(jnp.abs(ref), 1)
            max_scaled = max(max_scaled,
                             float(np.asarray(jnp.max(diff / scale))))
        exact = max_abs == 0.0
        repaired = False
        if not exact and not is_float:
            rec = AuditRecord(segment, name, False, max_abs, False,
                              time.perf_counter() - t0)
            cfg.audit_log.append(dataclasses.asdict(rec))
            raise StreamIntegrityError(
                f"integer-ring audit divergence in view {name!r} at "
                f"segment {segment}: max |live - reeval| = {max_abs} "
                "(exact rings cannot drift — state corruption)")
        if not exact and max_scaled > cfg.audit_tol and cfg.audit_repair:
            repair_view(engine, name, ref_dense)
            repaired = True
        rec = AuditRecord(segment, name, exact, max_abs, repaired,
                          time.perf_counter() - t0)
        records.append(rec)
        cfg.audit_log.append(dataclasses.asdict(rec))
    return records


def publish_meta(records: list[AuditRecord]) -> dict:
    """Audit provenance for a snapshot publication (repro.serve): the
    serving plane publishes *after* the boundary audit, and this stamps
    the generation with what that audit found — readers of a generation
    can tell whether it was audited clean, repaired in place, or never
    audited at all (empty meta)."""
    if not records:
        return {}
    return dict(audited=True,
                audit_exact=all(r.exact for r in records),
                repaired=sorted(r.view for r in records if r.repaired))


def reevaluate_from_base(engine) -> dict[str, float]:
    """Full self-heal: rebuild *every* materialized view from the stored
    base relations, preserving each view's storage backend (and sparse
    capacity where it still fits).  The strongest rung of the
    ``StreamSupervisor`` escalation ladder.  Returns per-view max
    absolute correction as telemetry."""
    store = reference_store(engine)
    drift: dict[str, float] = {}
    for name in list(engine.views):
        ref_dense = storage_mod.as_dense(store[name])
        live_dense = storage_mod.as_dense(engine.views[name])
        max_abs = 0.0
        for c in ref_dense.ring.components:
            ref = jnp.asarray(ref_dense.payload[c])
            live = jnp.asarray(live_dense.payload[c]).astype(ref.dtype)
            diff = jnp.abs(live - ref)
            diff = jnp.where(jnp.isnan(diff), jnp.inf, diff)
            max_abs = max(max_abs, float(np.asarray(jnp.max(diff))))
        drift[name] = max_abs
        repair_view(engine, name, ref_dense)
    return drift


__all__ = [
    "AuditRecord",
    "DeadLetter",
    "DeadLetterLog",
    "IntegrityConfig",
    "POLICIES",
    "REASON_DTYPE",
    "REASON_KEY_DOMAIN",
    "REASON_NONFINITE",
    "REASON_SCHEMA",
    "StreamIntegrityError",
    "admit_stream",
    "audit_engine",
    "batch_schema_errors",
    "flush_dead_letters",
    "reasons_of",
    "reevaluate_from_base",
    "reference_store",
    "repair_view",
    "sanitize_batch",
    "validate_rows",
]
