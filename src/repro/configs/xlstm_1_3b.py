"""xlstm-1.3b [ssm] — alternating sLSTM + mLSTM blocks.

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.
[arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections; there is no
separate MLP.  The mLSTM matrix-memory update C += v kᵀ is literally the
paper's "factorizable (rank-1) update" — see DESIGN.md §5.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(kind="xlstm", d_state=0, expand=2, chunk=256),
    block_pattern=("mlstm", "slstm"),
    optimizer="adamw",
    remat="full",
    source="arXiv:2405.04517; unverified",
)
