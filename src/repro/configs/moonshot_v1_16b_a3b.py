"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=163840,
MoE 64e top-6.  [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408, n_shared=2),
    rope_theta=50000.0,
    optimizer="adamw",
    remat="full",
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
