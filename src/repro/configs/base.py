"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload cell
is an (arch, ShapeSpec) pair.  ``reduced()`` produces the CPU-smoke variant
of any config (same family/topology, tiny dims).  The FULL configs are only
ever lowered abstractly (dry-run); smoke tests and examples use reduced
configs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0           # shared (always-on) experts
    every_k_layers: int = 1     # MoE replaces the MLP on layers where (idx % every_k == every_k-1)
    router_noise: float = 0.0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba (jamba) / xLSTM parameters."""
    kind: str = "mamba"        # "mamba" | "mlstm" | "slstm"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256           # chunkwise-parallel scan chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    qkv_bias: bool = False
    attn_kind: str = "gqa"     # gqa | mla
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # layer pattern, as a repeating period of block kinds; None = all "attn".
    # e.g. jamba: ("mamba",)*3 + ("attn",) + ("mamba",)*4 with MoE every 2.
    block_pattern: Optional[tuple] = None
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp: bool = False          # multi-token-prediction auxiliary head (deepseek)
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: Optional[str] = None       # None | "vision" | "audio"
    n_frontend_tokens: int = 256         # patch/frame count supplied by the stub
    sliding_window: Optional[int] = None # attention window for long-context cells
    act_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    optimizer: str = "adamw"             # adamw | adafactor (memory plan)
    remat: str = "full"                  # full | dots | none
    source: str = ""                     # provenance tag from the brief

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 512 so it shards over the model
        axis (16) and aligns with the 128-lane MXU (Megatron-style)."""
        return -(-self.vocab_size // 512) * 512

    @property
    def layer_pattern(self) -> tuple:
        if self.block_pattern is None:
            return ("attn",)
        return self.block_pattern

    @property
    def n_periods(self) -> int:
        p = len(self.layer_pattern)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    def is_moe_layer(self, idx_in_period: int) -> bool:
        if self.moe is None:
            return False
        k = self.moe.every_k_layers
        return idx_in_period % k == k - 1

    def supports_long_context(self) -> bool:
        """True iff the arch has a sub-quadratic path for 500k decode."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True  # none of the assigned archs is encoder-only

    def reduced(self) -> "ArchConfig":
        """Tiny same-topology variant for CPU smoke tests."""
        period = len(self.layer_pattern)
        moe = None
        if self.moe is not None:
            # capacity_factor = n_experts ⇒ C = T·k: no token ever drops, so
            # reduced-config decode exactly matches batched prefill (capacity
            # dropping is batch-dependent by design in the full configs).
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert_ff=64,
                n_shared=min(self.moe.n_shared, 1), capacity_factor=4.0)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                            qk_nope_head_dim=8, qk_rope_head_dim=8,
                            v_head_dim=8)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=4, chunk=8)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2 * period,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            moe=moe,
            mla=mla,
            ssm=ssm,
            n_enc_layers=2 if self.enc_dec else 0,
            n_frontend_tokens=16 if self.frontend else 0,
            sliding_window=None if self.sliding_window is None else 32,
            act_dtype="float32",
            param_dtype="float32",
        )


# ---------------------------------------------------------------------------
# Workload shapes (LM-family: identical 4-shape set for every arch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "deepseek_v3_671b",
    "moonshot_v1_16b_a3b",
    "llama3_2_3b",
    "llama3_2_1b",
    "qwen2_1_5b",
    "granite_3_2b",
    "xlstm_1_3b",
    "paligemma_3b",
    "seamless_m4t_large_v2",
    "jamba_v0_1_52b",
)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(include_skipped: bool = False):
    """The 40 (arch × shape) baseline cells; yields (arch_id, shape, skipped?)."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not cfg.supports_long_context()
            if include_skipped or not skip:
                yield a, s, skip
