"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887; hf]

Period of 8 layers: attention at position 3 (1 attn : 7 mamba), MoE MLP on
every second layer (every_k_layers=2).  For long_500k the attention layers
use a sliding window; the Mamba layers carry the long context in O(1)
state — this arch RUNS the long-context cell.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert_ff=14336, every_k_layers=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk=256),
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    sliding_window=4096,
    rope_theta=10000.0,
    optimizer="adafactor",
    remat="full",
    source="arXiv:2403.19887; hf",
)
