"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
[arXiv:2308.11596; hf]

Enc-dec: 24 encoder + 24 decoder layers over the same width.  The audio
frontend (w2v-BERT conformer feature extractor) is a STUB: ``input_specs()``
supplies precomputed frame embeddings [B, n_frames, d_model] to the encoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    enc_dec=True,
    n_enc_layers=24,
    frontend="audio",
    n_frontend_tokens=1024,
    rope_theta=10000.0,
    optimizer="adamw",
    remat="full",
    source="arXiv:2308.11596; hf",
)
