"""paligemma-3b [vlm] — SigLIP + gemma backbone.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
[arXiv:2407.07726; hf]

The SigLIP vision frontend is a STUB: ``input_specs()`` supplies
precomputed patch embeddings [B, n_patches, d_model]; the backbone
prepends them (prefix-LM style) to the token embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="vision",
    n_frontend_tokens=256,
    rope_theta=10000.0,
    tie_embeddings=True,
    optimizer="adamw",
    remat="full",
    source="arXiv:2407.07726; hf",
)
