"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H (GQA kv=128) d_ff=2048(expert) vocab=129280,
MoE 256e top-8.  [arXiv:2412.19437; hf]

Memory plan: adafactor (factored moments) + bf16 params — full fp32 Adam
state for 671B does not fit a 256-chip v5e pod (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=2048,
    vocab_size=129280,
    attn_kind="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert_ff=2048, n_shared=1),
    mtp=True,
    rope_theta=10000.0,
    optimizer="adafactor",
    remat="full",
    source="arXiv:2412.19437; hf",
)
