"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state.  Shapes:
  single-pod:  (16, 16)    axes ("data", "model")   — 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    assert len(devices) >= n, (
        f"need {n} devices for mesh {shape}; have {len(devices)} — run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py sets this)")
    arr = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    devices = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(devices, ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_size(mesh) -> int:
    return int(mesh.shape.get("model", 1))
