"""Logical-axis → mesh-axis sharding rules (MaxText-style, divisibility-aware).

Every parameter / activation / cache tensor carries *logical* axis names
(see models/layers.P).  This module resolves them against a mesh:

  * candidates are tried in order; a candidate is accepted only if the dim
    is divisible by the product of its mesh-axis sizes AND none of its mesh
    axes is already used by another dim of the same tensor;
  * the DP placeholder expands to ("pod", "data") on the multi-pod mesh and
    ("data",) on the single-pod mesh;
  * anything unresolvable falls back to replication — e.g. llama3.2-3b's 24
    q-heads don't divide the 16-way model axis, so its attention weights
    replicate across TP while its MLP still shards (see DESIGN.md §4).

The same rules drive parameter shardings (dry-run in_shardings), optimizer
state, decode caches, and ``shd()`` activation constraints inside the
model code.
"""
from __future__ import annotations

import contextlib
import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.layers import P, pop_constrainer, push_constrainer

DP = "__dp__"   # expands to all data-parallel axes ("pod" folds into DP)

# parameter logical axes
PARAM_RULES: dict = {
    "vocab": [("model",)],
    "embed": [(DP,)],            # FSDP shard of the model dimension
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "experts": [("model",)],     # expert parallelism
    "inner": [("model",)],       # mamba/mlstm expanded dim
    "q_lora": [], "kv_lora": [], "head_dim": [], "state_dim": [],
    "embed2": [], "inner2": [], "expert_ff": [], "layers": [],
}

# activation / cache logical axes
ACT_RULES: dict = {
    "batch": [(DP,), ("data",)],
    "kv_batch": [(DP,), ("data",)],
    # decode caches shard their seq axis over the TP axis (vLLM-style);
    # attention over the sharded axis becomes partial-softmax + all-reduce.
    # When batch is unshardable (long_500k, B=1) the combined candidate
    # claims every axis.
    "kv_seq": [(DP, "model"), ("model",), (DP,), ("data",)],
    "seq": [],
    "vocab_act": [("model",)],
    "heads_act": [("model",)],
    "experts_act": [("model",)],
    "inner_act": [("model",)],
    "embed_act": [],
    "kv_heads": [("model",)],
    "kv_lora": [],
    "vocab": [("model",)],
}

ALL_RULES = {**ACT_RULES, **PARAM_RULES}


def _expand(cand: tuple, mesh) -> tuple[str, ...]:
    out: list[str] = []
    for a in cand:
        if a == DP:
            out.extend(x for x in ("pod", "data") if x in mesh.axis_names)
        elif a in mesh.axis_names:
            out.append(a)
    return tuple(out)


def resolve_spec(mesh, axes: Sequence, dims: Sequence[int],
                 rules: dict | None = None) -> PartitionSpec:
    rules = rules if rules is not None else ALL_RULES
    used: set[str] = set()
    entries = []
    for name, dim in zip(axes, dims):
        chosen = None
        for cand in rules.get(name, []) if name is not None else []:
            axs = _expand(cand, mesh)
            if not axs:
                continue
            size = int(np.prod([mesh.shape[a] for a in axs]))
            if size > 1 and dim % size == 0 and not (set(axs) & used):
                chosen = axs
                break
        if chosen:
            used |= set(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def param_sharding(mesh, spec: P, rules: dict | None = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(mesh, spec.axes, spec.shape, rules))


def spec_to_sharding_fn(mesh, rules: dict | None = None):
    return lambda s: param_sharding(mesh, s, rules)


def tree_shardings(mesh, spec_tree, rules: dict | None = None):
    """Map a P-spec tree to a NamedSharding tree."""
    return jax.tree.map(lambda s: param_sharding(mesh, s, rules), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation constrainer (models call shd(x, *logical_axes))
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def activation_sharding(mesh, rules: dict | None = None):
    def constrain(x, axes):
        if len(axes) != x.ndim:
            return x
        spec = resolve_spec(mesh, axes, x.shape, rules)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    push_constrainer(constrain)
    try:
        yield
    finally:
        pop_constrainer()


# ---------------------------------------------------------------------------
# Optimizer-state specs mirror parameter specs
# ---------------------------------------------------------------------------
def opt_state_specs(opt_name: str, param_specs):
    """P-spec tree matching optimizers.{adamw,adafactor,sgd}.init output."""
    from repro.optim.optimizers import _FactoredSlot  # noqa: F401

    def adamw_slot(s: P):
        return P(s.shape, s.axes, "zeros")

    if opt_name == "adamw":
        return {
            "step": P((), (), "zeros"),
            "m": jax.tree.map(adamw_slot, param_specs, is_leaf=lambda x: isinstance(x, P)),
            "v": jax.tree.map(adamw_slot, param_specs, is_leaf=lambda x: isinstance(x, P)),
        }
    if opt_name == "adafactor":
        def slot(s: P):
            factored = (len(s.shape) >= 2 and s.shape[-1] >= 128
                        and s.shape[-2] >= 128)
            if factored:
                return _FactoredSlot(
                    vr=P(s.shape[:-1], s.axes[:-1], "zeros"),
                    vc=P(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:], "zeros"),
                )
            return P(s.shape, s.axes, "zeros")

        return {
            "step": P((), (), "zeros"),
            "v": jax.tree.map(slot, param_specs, is_leaf=lambda x: isinstance(x, P)),
        }
    if opt_name == "sgd":
        return {"step": P((), (), "zeros")}
    raise ValueError(opt_name)
