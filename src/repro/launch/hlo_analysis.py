"""Trip-count-aware HLO analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE — useless
for models that lax.scan over layers (and microbatches).  This module
parses ``compiled.as_text()`` (the post-SPMD, per-device module) and walks
the computation graph with multipliers:

  * while bodies  × trip count (extracted from the condition's constant),
  * fusions/calls × 1,
  * nested loops multiply (microbatch scan × layer scan × ...).

Per computation it accumulates:
  * ``dot_flops``   — 2 · result_elems · contracted_size per dot,
  * ``elem_flops``  — one flop per element of arithmetic/reduce ops (VPU),
  * ``bytes``       — estimated HBM traffic,
  * ``collective_bytes`` by op type (result-shape bytes per op).

HBM-traffic model (what makes the estimate honest inside loops):
  * a fusion reads each parameter once and writes its root once — EXCEPT
    parameters that are only consumed by slicing ops (dynamic-slice /
    gather / slice), which read only the slice (layer-stacked weights
    inside a scan!), and dynamic-update-slice roots, which touch only the
    updated region (in-place KV-cache writes);
  * the same slicing rules apply to top-level instructions;
  * fusion internals never touch HBM.

All quantities are PER DEVICE (the module is the partitioned program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_PARAM_RE = re.compile(r"parameter\((\d+)\)")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

# coarse attribution tags, matched against jax op_name metadata paths
TAGS = (
    ("attention", ("flash_attention", "gqa_", "mla_", "decode_attention",
                   "_plain_attention", "apply_rope")),
    ("moe", ("moe_", "top_k", "argsort", "searchsorted")),
    ("ssm", ("mamba", "mlstm", "slstm", "associative_scan")),
    ("mlp", ("swiglu", "gelu_mlp")),
    ("embed_logits", ("take", "_embed", "_logits", "cross_entropy",
                      "logsumexp")),
    ("norm", ("rms_norm",)),
    ("optimizer", ("adafactor", "adamw", "sgd", "global_norm", "upd")),
)


def _tag_of(line: str) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "other"
    path = m.group(1)
    for tag, keys in TAGS:
        if any(k in path for k in keys):
            return tag
    return "other"

_SLICING_OPS = {"dynamic-slice", "slice", "gather"}

# VMEM residency model: loop-invariant operands up to this size are assumed
# resident across while iterations (v5e has 128 MB VMEM) and charged once
# per loop invocation instead of once per iteration.  Without this, the
# xlstm cell's recurrent weights (16.8 MB, re-read 4096× per layer by the
# estimator) dominate the memory term 10× over reality.
VMEM_RESIDENT_BYTES = 64 * 2**20

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "negate",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "power", "compare", "select", "and", "or", "xor", "not",
    "abs", "sign", "floor", "ceil", "round-nearest-afz", "cosine", "sine",
    "logistic", "atan2", "remainder", "clamp", "reduce", "map",
    "reduce-window",
}

_ZERO_BYTE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "reshape", "custom-call",
}


def _type_bytes_elems(type_str: str) -> tuple[float, float]:
    total_b = 0.0
    total_e = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str
    operands: list
    result_bytes: float
    result_elems: float


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    edges: list = dataclasses.field(default_factory=list)
    is_fusion_body: bool = False
    consts: list = dataclasses.field(default_factory=list)
    # fusion-call interface costs
    param_reads: dict = dataclasses.field(default_factory=dict)  # idx -> bytes
    root_write: float = 0.0
    # per-module attribution (op_name metadata)
    bytes_by_tag: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_tag: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # VMEM-resident loop-invariant reads, charged once per loop invocation
    invariant_bytes: float = 0.0
    invariant_names: set = dataclasses.field(default_factory=set)


def _parse_computations(text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                name = m.group(1)
                comps[name] = cur = []
                if line.startswith("ENTRY"):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.append(line)
    return comps, entry


def _analyze_computation(lines: list[str]) -> CompStats:
    st = CompStats()
    instrs: dict[str, _Instr] = {}
    order: list[_Instr] = []
    params: dict[str, int] = {}      # instr name -> parameter index
    root: _Instr | None = None

    for line in lines:
        st.consts.extend(int(c) for c in _CONST_RE.findall(line))
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        nbytes, nelems = _type_bytes_elems(type_str)
        rest = line[m.end() - 1:]
        # operands = %names referenced before any attribute section
        argpart = rest.split("),", 1)[0]
        operands = _OPERAND_RE.findall(argpart)
        ins = _Instr(name, type_str, op, line, operands, nbytes, nelems)
        instrs[name] = ins
        order.append(ins)
        if op == "parameter":
            pm = _PARAM_RE.search(line)
            if pm:
                params[name] = int(pm.group(1))
        if line.lstrip().startswith("ROOT"):
            root = ins

        # ---- edges -------------------------------------------------------
        if op == "while":
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                st.edges.append((body, ("trip", cond)))
                st.edges.append((cond, ("trip", cond)))
            continue
        cm = _CALLS_RE.search(line)
        if cm:
            st.edges.append((cm.group(1), ("fusion", name)))
        tm = _TO_APPLY_RE.search(line)
        if tm:
            st.edges.append((tm.group(1), ("call", None)))
        bm = _BRANCH_RE.search(line)
        if bm:
            for b in _OPERAND_RE.findall(bm.group(1)):
                st.edges.append((b, ("call", None)))

        # ---- flops -------------------------------------------------------
        if op == "dot":
            lhs_cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contracted = 1
            if lhs_cd and operands:
                lhs = instrs.get(operands[0])
                if lhs is not None:
                    sm = _SHAPE_RE.search(lhs.type_str)
                    if sm and sm.group(2):
                        dims = [int(d) for d in sm.group(2).split(",")]
                        for ci in lhs_cd.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                contracted *= dims[int(ci)]
            st.dot_flops += 2.0 * nelems * contracted
            st.flops_by_tag[_tag_of(line)] += 2.0 * nelems * contracted
        elif op in _ELEMENTWISE:
            st.elem_flops += nelems
            st.flops_by_tag[_tag_of(line)] += nelems

        # ---- collectives ---------------------------------------------
        base_op = op.replace("-start", "")
        if base_op in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"):
            st.collective_bytes[base_op] += nbytes

    # ---- per-parameter effective reads (for fusion call sites) -----------
    consumers: dict[str, list[_Instr]] = defaultdict(list)
    for ins in order:
        for o in ins.operands:
            if o in instrs:
                consumers[o].append(ins)
    for pname, pidx in params.items():
        full = instrs[pname].result_bytes
        cons = consumers.get(pname, [])
        if not cons:
            eff = 0.0
        elif all(c.op in _SLICING_OPS and c.operands
                 and c.operands[0] == pname for c in cons):
            # only sliced: reads just the slices (stacked weights in a scan)
            eff = min(sum(c.result_bytes for c in cons), full)
        elif all(c.op == "dynamic-update-slice" and c.operands
                 and c.operands[0] == pname for c in cons):
            # only updated in place (aliased KV-cache buffer): no read
            eff = 0.0
        else:
            eff = full
        st.param_reads[pidx] = eff
    def _write_cost(r: _Instr) -> float:
        # look through convert/bitcast wrappers around an in-place update
        while r.op in ("convert", "bitcast") and r.operands and r.operands[0] in instrs:
            r = instrs[r.operands[0]]
        if r.op == "dynamic-update-slice" and len(r.operands) >= 2:
            upd = instrs.get(r.operands[1])
            return 2.0 * (upd.result_bytes if upd else r.result_bytes)
        return r.result_bytes

    if root is not None:
        if root.op == "tuple":  # multi-output fusion: charge each output
            st.root_write = sum(
                _write_cost(instrs[o]) for o in root.operands if o in instrs)
        else:
            st.root_write = _write_cost(root)

    # ---- loop-invariant detection (while bodies: gte(arg, i) passed back
    # unchanged at tuple position i) -> VMEM-resident read model ----------
    invariant: set[str] = set()
    param_names = [n for n, i in params.items()]
    if root is not None and root.op == "tuple" and len(param_names) == 1:
        arg = param_names[0]
        gte_idx: dict[str, int] = {}
        for ins in order:
            if ins.op == "get-tuple-element" and ins.operands == [arg]:
                mi = re.search(r"index=(\d+)", ins.line)
                if mi:
                    gte_idx[ins.name] = int(mi.group(1))

        def resolve(name: str) -> str:
            # follow copy/bitcast passthrough chains back to their source
            seen = 0
            while name in instrs and instrs[name].op in ("copy", "bitcast") \
                    and instrs[name].operands and seen < 20:
                name = instrs[name].operands[0]
                seen += 1
            return name

        for i, o in enumerate(root.operands):
            src = resolve(o)
            if gte_idx.get(src) == i and \
                    instrs[src].result_bytes <= VMEM_RESIDENT_BYTES:
                invariant.add(src)
        # copies/converts/bitcasts of invariants stay resident too
        changed = True
        while changed:
            changed = False
            for ins in order:
                if ins.name in invariant:
                    continue
                if ins.op in ("copy", "convert", "bitcast", "reshape",
                              "transpose") and ins.operands and \
                        ins.operands[0] in invariant and \
                        ins.result_bytes <= VMEM_RESIDENT_BYTES:
                    invariant.add(ins.name)
                    changed = True
        st.invariant_bytes = sum(instrs[n].result_bytes for n in invariant)
        st.invariant_names = invariant

    # ---- top-level HBM bytes (non-fusion computations use this) ----------
    for ins in order:
        op = ins.op
        if op in _ZERO_BYTE_OPS or op.endswith("-done") or op == "while":
            continue
        if op in _SLICING_OPS:
            b = 2.0 * ins.result_bytes  # read slice + write result
        elif op == "dynamic-update-slice":
            upd = instrs.get(ins.operands[1]) if len(ins.operands) >= 2 else None
            b = 2.0 * (upd.result_bytes if upd else ins.result_bytes)
        else:
            b = ins.result_bytes if ins.name not in invariant else 0.0
            for o in ins.operands:
                if o in instrs and o not in invariant:
                    b += instrs[o].result_bytes
        st.bytes += b
        st.bytes_by_tag[_tag_of(ins.line)] += b
    return st, instrs


def parse_hlo(text: str):
    raw, entry = _parse_computations(text)
    comps: dict[str, CompStats] = {}
    all_instrs: dict[str, dict] = {}
    for name, lines in raw.items():
        comps[name], all_instrs[name] = _analyze_computation(lines)
    # mark fusion bodies + fix call-site bytes for fusions
    fusion_sites: list[tuple[str, str, str]] = []  # (caller, callee, instr)
    for cname, st in comps.items():
        for callee, (kind, site) in st.edges:
            if kind == "fusion" and callee in comps:
                comps[callee].is_fusion_body = True
                fusion_sites.append((cname, callee, site))
    for cname, callee, site in fusion_sites:
        caller_instrs = all_instrs[cname]
        ins = caller_instrs.get(site)
        body = comps[callee]
        if ins is None:
            continue
        # replace the generic operand+result charge with the interface model
        inv = comps[cname].invariant_names
        generic = ins.result_bytes + sum(
            caller_instrs[o].result_bytes for o in ins.operands
            if o in caller_instrs and o not in inv)
        eff = body.root_write
        for i, o in enumerate(ins.operands):
            if o not in inv:  # VMEM-resident operands read once per loop
                eff += body.param_reads.get(i, 0.0)
        comps[cname].bytes += eff - generic
        comps[cname].bytes_by_tag[_tag_of(ins.line)] += eff - generic
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    seen, stack, best = set(), [cond_name], 1
    while stack:
        c = stack.pop()
        if c in seen or c not in comps:
            continue
        seen.add(c)
        for v in comps[c].consts:
            best = max(best, v)
        for callee, _ in comps[c].edges:
            stack.append(callee)
    return best


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    elem_flops: float
    bytes: float
    collective_bytes: dict
    bytes_by_tag: dict = dataclasses.field(default_factory=dict)
    flops_by_tag: dict = dataclasses.field(default_factory=dict)

    @property
    def flops(self) -> float:
        return self.dot_flops + self.elem_flops

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _merge(dst: dict, src: dict, mult: float) -> None:
    for k, v in src.items():
        dst[k] = dst.get(k, 0.0) + mult * v


def analyze(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    memo: dict[str, tuple] = {}

    def visit(name: str, visiting: frozenset) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in visiting:
            return (0.0, 0.0, 0.0, {}, {}, {})
        c = comps[name]
        dot, elem = c.dot_flops, c.elem_flops
        byt = 0.0 if c.is_fusion_body else c.bytes
        coll = dict(c.collective_bytes)
        btag = {} if c.is_fusion_body else dict(c.bytes_by_tag)
        ftag = dict(c.flops_by_tag)
        for callee, (kind, cond) in c.edges:
            mult = _trip_count(comps, cond) if kind == "trip" else 1
            cd, ce, cb, cc, cbt, cft = visit(callee, visiting | {name})
            dot += mult * cd
            elem += mult * ce
            byt += mult * cb
            if kind == "trip" and callee in comps:
                # invariant (VMEM-resident) reads: once per loop invocation
                byt += comps[callee].invariant_bytes
            _merge(coll, cc, mult)
            _merge(btag, cbt, mult)
            _merge(ftag, cft, mult)
        memo[name] = (dot, elem, byt, coll, btag, ftag)
        return memo[name]

    dot, elem, byt, coll, btag, ftag = visit(entry, frozenset())
    return HloCosts(dot_flops=dot, elem_flops=elem, bytes=byt,
                    collective_bytes=coll, bytes_by_tag=btag,
                    flops_by_tag=ftag)
