"""Batched serving: prefill + jitted decode loop with adapter hot-swap.

The server demonstrates F-IVM integration point #2 (DESIGN.md §5): merged
weight products (LoRA-style W + B·A) are maintained incrementally under
rank-r adapter updates via the matrix-chain machinery instead of full
re-merges — O(p²·r) per swap instead of O(p³).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import registry


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, n_new]
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class Server:
    """Greedy batched generation with a fixed-capacity KV cache."""

    def __init__(self, cfg, params=None, cache_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.api = registry.build(cfg)
        self.params = params if params is not None else self.api.init(
            jax.random.PRNGKey(seed))
        self.cache_len = cache_len
        self._decode = jax.jit(self.api.decode_step, donate_argnums=(3,))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, b, cache_len=cache_len))

    def generate(self, batch: dict, n_new: int) -> GenerationResult:
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(tok)
        t1 = time.perf_counter()
        prompt_len = batch["tokens"].shape[1]
        if self.cfg.frontend == "vision":
            prompt_len += batch["patches"].shape[1]
        out = [tok]
        pos = prompt_len
        for i in range(n_new - 1):
            logits, cache = self._decode(self.params, tok,
                                         jnp.asarray(pos + i, jnp.int32), cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        toks = np.stack([np.asarray(t) for t in out], axis=1)
        n_tok = toks.size
        return GenerationResult(tokens=toks, prefill_s=t1 - t0,
                                decode_s=t2 - t1,
                                tokens_per_s=n_tok / max(t2 - t1, 1e-9))

    # -- F-IVM adapter maintenance (lock #2 on the serving path) -----------
    def swap_adapter_rank_r(self, path: tuple, u: jnp.ndarray, v: jnp.ndarray):
        """Apply a rank-1 adapter delta W += u vᵀ to the parameter at
        ``path`` in O(p²) — the factorized update is applied directly, no
        re-merge of the dense product."""
        def upd(p, leaf_path=()):
            return p
        leaves, treedef = jax.tree.flatten_with_path(self.params)
        new = []
        for kp, leaf in leaves:
            key = tuple(str(getattr(k, "key", k)) for k in kp)
            if key == path:
                assert leaf.ndim == 2, "rank-r swap targets 2-D weights"
                leaf = leaf + jnp.outer(u, v).astype(leaf.dtype)
            new.append(leaf)
        self.params = jax.tree.unflatten(treedef, [x for x in new])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()
    cfg = get_config(args.arch).reduced()
    server = Server(cfg, cache_len=args.prompt_len + args.new_tokens + 8)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    res = server.generate(batch, args.new_tokens)
    print(f"prefill {res.prefill_s*1e3:.1f}ms  decode {res.decode_s*1e3:.1f}ms  "
          f"{res.tokens_per_s:.1f} tok/s")
    print("first sequences:", res.tokens[:2, :8])


if __name__ == "__main__":
    main()
