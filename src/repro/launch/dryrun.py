import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-only artifact control: XLA:CPU float-normalizes bf16 dots to f32
    # and LICM then hoists full f32 copies of loop-invariant tensors (all
    # stacked weights + KV caches) out of the layer scan, inflating both
    # memory_analysis and HBM-traffic estimates by >2x.  On TPU bf16 is
    # native and these converts don't exist; disabling the hoist keeps the
    # per-device memory/traffic picture faithful to the TPU target.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract the roofline terms.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` must succeed for the 16×16 single-pod mesh and the
2×16×16 multi-pod mesh for every cell; ``memory_analysis()`` proves the
per-device footprint; the trip-count-aware HLO analysis supplies FLOPs /
bytes / collective-bytes for EXPERIMENTS.md §Roofline.

Usage:
    python -m repro.launch.dryrun --arch llama3_2_3b --shape train_4k
    python -m repro.launch.dryrun --arch all [--multi-pod] --out results/
"""
import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.models import registry                            # noqa: E402
from repro.models.layers import P, abstract_from_spec        # noqa: E402
from repro.optim.optimizers import make_optimizer            # noqa: E402

from . import hlo_analysis                                   # noqa: E402
from .mesh import make_production_mesh                       # noqa: E402
from .sharding import (activation_sharding, spec_to_sharding_fn,  # noqa: E402
                       param_sharding)
from .train import abstract_train_args, make_train_plan, make_train_step  # noqa: E402

# v5e-class hardware constants (roofline denominators)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link per chip


def _abstract_cache(cfg, api, batch: int, seq: int, mesh):
    to_sh = spec_to_sharding_fn(mesh)
    spec = api.cache_spec(batch, seq)
    dtypes = jax.eval_shape(lambda: api.init_cache(batch, seq, jnp.dtype(cfg.act_dtype)))

    def leaf(s, abs_leaf):
        return jax.ShapeDtypeStruct(s.shape, abs_leaf.dtype, sharding=to_sh(s))

    return jax.tree.map(leaf, spec, dtypes, is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               do_compile: bool = True, extra_tag: str = ""):
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "mesh": "2x16x16" if multi_pod else "16x16",
                "reason": "pure full-attention arch: no sub-quadratic path "
                          "at 524288 tokens (DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    api = registry.build(cfg)
    to_sh = spec_to_sharding_fn(mesh)
    t0 = time.time()

    if shape.kind == "train":
        optimizer = make_optimizer(cfg.optimizer, 3e-4)
        plan = make_train_plan(cfg, shape, mesh)
        step = make_train_step(cfg, api, optimizer, plan)
        args = abstract_train_args(cfg, api, optimizer, shape, mesh)
        jitted = jax.jit(step, donate_argnums=(0, 1))
    elif shape.kind == "prefill":
        params = abstract_from_spec(api.specs, jnp.dtype(cfg.param_dtype), to_sh)
        batch = registry.abstract_batch(cfg, shape, to_sh)
        step = lambda p, b: api.prefill(p, b, cache_len=shape.seq_len)
        args = (params, batch)
        jitted = jax.jit(step)
    else:  # decode
        params = abstract_from_spec(api.specs, jnp.dtype(cfg.param_dtype), to_sh)
        inp = registry.abstract_batch(cfg, shape, to_sh)
        cache = _abstract_cache(cfg, api, shape.global_batch, shape.seq_len, mesh)
        step = lambda p, tok, pos, c: api.decode_step(p, tok, pos, c)
        args = (params, inp["token"], inp["pos"], cache)
        jitted = jax.jit(step, donate_argnums=(3,))

    with activation_sharding(mesh):
        lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "status": "lowered", "lower_s": round(t_lower, 1),
        "n_params": api.n_params(), "n_active_params": api.n_active_params(),
        "tag": extra_tag,
    }
    if not do_compile:
        return record

    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)
    record["status"] = "compiled"

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                record[k] = int(v)
        args_b = record.get("argument_size_in_bytes", 0)
        alias_b = record.get("alias_size_in_bytes", 0)
        out_b = record.get("output_size_in_bytes", 0)
        tmp_b = record.get("temp_size_in_bytes", 0)
        record["peak_bytes_per_device"] = args_b + out_b + tmp_b - alias_b

    ca = compiled.cost_analysis()
    if ca:
        record["xla_flops_once"] = float(ca.get("flops", 0.0))
        record["xla_bytes_once"] = float(ca.get("bytes accessed", 0.0))

    costs = hlo_analysis.analyze(compiled.as_text())
    record["hlo_dot_flops"] = costs.dot_flops
    record["hlo_elem_flops"] = costs.elem_flops
    record["hlo_bytes"] = costs.bytes
    record["collective_bytes"] = dict(costs.collective_bytes)
    record["bytes_by_tag"] = dict(costs.bytes_by_tag)
    record["flops_by_tag"] = dict(costs.flops_by_tag)

    # roofline terms (per-device quantities over per-chip peaks)
    record["compute_term_s"] = costs.flops / PEAK_FLOPS
    record["memory_term_s"] = costs.bytes / HBM_BW
    record["collective_term_s"] = costs.total_collective_bytes / ICI_BW
    terms = {"compute": record["compute_term_s"],
             "memory": record["memory_term_s"],
             "collective": record["collective_term_s"]}
    record["bottleneck"] = max(terms, key=terms.get)

    # model flops (useful work): 6·N_active·D for train, 2·N_active per token
    n_act = api.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        record["model_flops"] = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        record["model_flops"] = 2.0 * n_act * tokens
    else:
        record["model_flops"] = 2.0 * n_act * shape.global_batch
    total_hlo = costs.flops * mesh.devices.size
    record["useful_flop_ratio"] = (record["model_flops"] / total_hlo
                                   if total_hlo else 0.0)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for a in archs:
        for s in shapes:
            try:
                rec = lower_cell(a, s, multi_pod=args.multi_pod,
                                 do_compile=not args.no_compile)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": a, "shape": s,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            print(json.dumps({k: v for k, v in rec.items() if k != "traceback"}))
            if rec.get("status") == "FAILED":
                print(rec.get("traceback", ""))
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                fn = f"{a}__{s}__{rec.get('mesh', 'x')}.json"
                with open(os.path.join(args.out, fn), "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
