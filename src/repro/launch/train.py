"""Training step construction + the end-to-end training driver.

``make_train_step(cfg, api, optimizer, n_microbatches, accum_dtype)``
returns a pure function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

that microbatches the global batch with lax.scan (gradient accumulation),
so activation memory is bounded by one microbatch regardless of the global
batch size.  The accumulation dtype is a per-arch memory-plan knob:
fp32 everywhere except the 671B config on a single pod (DESIGN.md §4).

The driver (``run_training``) adds the production loop: checkpoint/restart,
per-step deadlines (straggler surfacing), optional rank-r gradient
compression (runtime/compression.py — the paper's factorizable-update lock
applied to DP sync), and metric logging.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config
from repro.models import registry
from repro.models.layers import P, abstract_from_spec
from repro.optim import linear_warmup_cosine
from repro.optim.optimizers import Optimizer, make_optimizer

from . import sharding as shd_rules
from .mesh import dp_size, make_smoke_mesh


# ---------------------------------------------------------------------------
# Train plan: per-(arch, shape, mesh) microbatching + dtype policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TrainPlan:
    n_microbatches: int
    accum_dtype: Any
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000


def make_train_plan(cfg: ArchConfig, shape: ShapeSpec, mesh) -> TrainPlan:
    dp = dp_size(mesh)
    # sequences per device per microbatch, by activation footprint
    if cfg.d_model >= 4096:
        seqs = 1
    elif cfg.d_model >= 3072:
        seqs = 2
    else:
        seqs = 4
    n_micro = max(1, shape.global_batch // max(dp * seqs, 1))
    while shape.global_batch % n_micro or (shape.global_batch // n_micro) % min(dp, shape.global_batch):
        n_micro -= 1  # keep microbatch divisible by dp
    # adafactor configs (the ≥50B models) accumulate in bf16 on every mesh:
    # measured on jamba train_4k multi-pod, the fp32 accumulator pushed the
    # cell from fitting to 37.2 GiB/dev (EXPERIMENTS.md §Roofline)
    accum = jnp.bfloat16 if cfg.optimizer == "adafactor" else jnp.float32
    return TrainPlan(n_microbatches=max(n_micro, 1), accum_dtype=accum)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, api: registry.ModelAPI,
                    optimizer: Optimizer, plan: TrainPlan):
    """Gradient compression (runtime/compression.py) composes by wrapping
    ``optimizer`` with compressed_optimizer() before calling this."""
    n_micro = plan.n_microbatches

    def train_step(params, opt_state, batch):
        def split_micro(a):
            b = a.shape[0]
            return a.reshape(n_micro, b // n_micro, *a.shape[1:])

        micro = jax.tree.map(split_micro, batch)
        grad_fn = jax.value_and_grad(lambda p, b: api.loss(p, b), has_aux=True)

        def acc_body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda acc, gi: acc + gi.astype(acc.dtype), g_acc, g)
            return (g_acc, loss_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, plan.accum_dtype), params)
        (grads, loss_sum), _ = jax.lax.scan(acc_body, (g0, jnp.zeros((), jnp.float32)),
                                            micro)
        # stay in the accumulation dtype: materializing an fp32 grad tree
        # here costs +11.2 GB/dev on the 671B cell (§Perf iteration 3);
        # optimizers upcast per-leaf transiently inside their update.
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        new_params, new_opt = optimizer.update(params, opt_state, grads)
        metrics = {"loss": loss_sum / n_micro,
                   "grad_norm": _global_norm(grads)}
        return new_params, new_opt, metrics

    return train_step


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# Abstract inputs for the dry-run (ShapeDtypeStruct + NamedSharding)
# ---------------------------------------------------------------------------
def abstract_train_args(cfg, api, optimizer, shape, mesh):
    to_sh = shd_rules.spec_to_sharding_fn(mesh)
    params = abstract_from_spec(api.specs, jnp.dtype(cfg.param_dtype), to_sh)
    # exact opt-state dtypes/shapes via eval_shape; shardings from mirrored specs
    opt_abs = jax.eval_shape(optimizer.init, params)
    opt_specs = shd_rules.opt_state_specs(cfg.optimizer, api.specs)

    def attach(abs_leaf, spec_leaf):
        if isinstance(spec_leaf, P):
            sh = shd_rules.param_sharding(mesh, spec_leaf)
            return jax.ShapeDtypeStruct(abs_leaf.shape, abs_leaf.dtype, sharding=sh)
        return abs_leaf

    opt_state = jax.tree.map(attach, opt_abs, opt_specs,
                             is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))
    batch = registry.abstract_batch(cfg, shape, to_sh)
    return params, opt_state, batch


# ---------------------------------------------------------------------------
# Real-training driver (reduced configs on CPU; full configs on TPU)
# ---------------------------------------------------------------------------
def run_training(cfg: ArchConfig, *, steps: int = 100, batch_size: int = 8,
                 seq_len: int = 64, seed: int = 0, mesh=None,
                 checkpoint_dir: str | None = None, checkpoint_every: int = 50,
                 log_every: int = 10, data_iter=None, resume: bool = True,
                 step_deadline_s: float | None = None,
                 schedule_steps: int | None = None):
    """End-to-end trainer used by examples/train_lm.py and the fault-
    tolerance tests.  Returns (params, history)."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.lm_data import synthetic_lm_batches

    api = registry.build(cfg)
    mesh = mesh or make_smoke_mesh()
    shape = ShapeSpec("adhoc", seq_len, batch_size, "train")
    plan = make_train_plan(cfg, shape, mesh)
    # The LR schedule is a function of the TOTAL intended run length
    # (schedule_steps), which must stay fixed across checkpoint resumes for
    # bit-consistent continuation.  Short runs scale warmup to the horizon
    # and reduced (smoke-sized) configs use a livelier LR.
    horizon = schedule_steps or steps
    warmup = min(plan.warmup_steps, max(horizon // 10, 1))
    base_lr = 3e-3 if cfg.d_model <= 256 else plan.learning_rate
    lr = linear_warmup_cosine(base_lr, warmup, max(horizon, warmup + 1))
    optimizer = make_optimizer(cfg.optimizer, lr)
    key = jax.random.PRNGKey(seed)
    params = api.init(key)
    opt_state = optimizer.init(params)
    start_step = 0
    ckpt = None
    if checkpoint_dir is not None:
        ckpt = Checkpointer(checkpoint_dir)
        if resume:
            restored = ckpt.restore_latest((params, opt_state))
            if restored is not None:
                (params, opt_state), start_step = restored

    step_fn = jax.jit(make_train_step(cfg, api, optimizer, plan))
    if data_iter is None:
        data_iter = synthetic_lm_batches(cfg, shape, seed=seed,
                                         start_step=start_step)
    history = []
    for step in range(start_step, steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if step_deadline_s is not None and dt > step_deadline_s:
            print(f"[straggler] step {step} took {dt:.2f}s > {step_deadline_s}s")
        history.append({"step": step, "loss": loss, "time_s": dt})
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f}ms")
        if ckpt is not None and checkpoint_every and (step + 1) % checkpoint_every == 0:
            ckpt.save((params, opt_state), step + 1)
    if ckpt is not None:
        ckpt.save((params, opt_state), steps)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config — TPU only")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    run_training(cfg, steps=args.steps, batch_size=args.batch,
                 seq_len=args.seq, checkpoint_dir=args.ckpt)


if __name__ == "__main__":
    main()
